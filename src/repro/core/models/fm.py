"""iCD for Factorization Machines (paper §5.2.2).

FM (eq. 26) over the concatenated feature vector x = (x_c, z_i):

    ŷ(x) = b + Σ_l x_l w̃_l + Σ_{l<l'} ⟨w_l, w_l'⟩ x_l x_l'

is (k+2)-separable (eqs. 27–31). We lay the extended components out as
aligned columns of Φe ∈ R^{C×(k+2)} and Ψe ∈ R^{I×(k+2)}:

    column f < k : φ_f = Σ_l x_l w_{l,f}          ψ_f = Σ_l z_l h_{l,f}
    column k     : φ_spec (ctx bias+linear+pairs)  ones
    column k+1   : ones                            ψ_spec (item side)

so ŷ = ⟨Φe(c), Ψe(i)⟩ exactly. Gradients are sparse (eqs. 32–33): a context
embedding w_{l*,f*} feeds component f* (value x) and the ctx-special
component (value x·g, g = φ_{f*} − x·w_{l*,f*}); FM stays *linear* in every
single coordinate, so full Newton steps (η=1) are exact.

Sweep order per side: all k embedding dims (field-vectorized like MFSI),
then the linear weights, then (context side only) the global bias. One-hot
fields are exact; multi-hot fields use damped Jacobi (DESIGN.md §3) and the
second-order cross-slot residual drift is bounded by refreshing caches every
epoch. Runtime matches the paper: same flow/complexity as MFSI,
O(k² N_Z(X)) per epoch for the implicit part.

Fused padded path (``epoch_padded`` over ``mf_padded.PaddedInteractions``,
dispatched by ``hp.block_k``): per block of k_b dimensions one
``cd_slab_reduce`` over the k_b ψ columns PLUS the ψ_spec column yields
every per-context cache the layer updates need — q/u from Q, p2/p1/p0 from
the moment slab P — and the cross-dimension coupling that patches q for
later block columns (Δe = Δφ_j·ψ_j + Δφ_s·ψ_spec ⇒ Δq_f =
Δφ_j·P[·,j,f] + Δφ_s·P[·,s,f]); one rank-(k_b+1) ``cd_resid_patch``
closes the block. The linear-weight and bias stages run on the padded grid
with the same formulas.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import sweeps
from repro.core.design import Design, design_matmul, take_rows
from repro.core.gram import gram
from repro.core.implicit import implicit_objective
from repro.core.models.mf_padded import (
    PaddedInteractions,
    pad_interactions,
    reweight_padded,
    scatter_ctx_major,
    transfer_ctx_to_item,
    transfer_item_to_ctx,
)
from repro.core.models.mfsi import _field_layers
from repro.kernels import vmem
from repro.kernels.cd_sweep.ops import (
    cd_resid_patch,
    cd_resid_patch_gather,
    cd_slab_reduce,
    cd_slab_reduce_gather,
)
from repro.sparse.interactions import Interactions
from repro.sparse.segment import segment_sum

__all__ = ["FMParams", "FMHyperParams", "pad_interactions", "init",
           "phi_ext", "psi_ext", "export_psi", "build_phi", "predict",
           "epoch", "epoch_padded", "residuals", "residuals_padded",
           "objective", "fit"]


class FMParams(NamedTuple):
    b: jax.Array       # () global bias
    w_lin: jax.Array   # (p,)  context linear weights  (paper w̃)
    w: jax.Array       # (p, k) context embeddings
    h_lin: jax.Array   # (p',) item linear weights     (paper h̃)
    h: jax.Array       # (p', k) item embeddings


@dataclasses.dataclass(frozen=True)
class FMHyperParams:
    k: int
    alpha0: float = 1.0
    l2: float = 0.1
    l2_lin: float = 0.1
    eta: float = 1.0
    use_linear: bool = True
    use_bias: bool = True
    multi_hot_mode: str = "jacobi"  # 'jacobi' | 'slot'
    jacobi_eta: float = 0.5
    implementation: str = "xla"
    block_k: int = 0  # dims per fused slab-reduce/resid-patch dispatch on
    #                   the padded layout (epoch_padded): 0 = auto
    #                   (min(k, 8)), 1 = per-dimension baseline
    psi_dispatch: str = "gather"  # fused-path Ψ routing: 'gather' =
    #                   in-kernel gather (no (n, k_b+1, D_pad) intermediate;
    #                   auto-fallback on VMEM overflow), 'pregather' =
    #                   host-side pre-gathered tile


def init(key: jax.Array, p_ctx: int, p_item: int, k: int, sigma: float = 0.1) -> FMParams:
    kw, kh = jax.random.split(key)
    return FMParams(
        b=jnp.zeros((), jnp.float32),
        w_lin=jnp.zeros((p_ctx,), jnp.float32),
        w=sigma * jax.random.normal(kw, (p_ctx, k), dtype=jnp.float32),
        h_lin=jnp.zeros((p_item,), jnp.float32),
        h=sigma * jax.random.normal(kh, (p_item, k), dtype=jnp.float32),
    )


def _self_pairwise(design: Design, table: jax.Array, phi_m: jax.Array) -> jax.Array:
    """Σ_{l<l'} ⟨w_l,w_l'⟩ x_l x_l' = ½ Σ_f (φ_f² − Σ_l x_l² w_{l,f}²)."""
    sq_sum = jnp.zeros((design.n_rows,), jnp.float32)
    for field in design.fields:
        wsq = jnp.take(table * table, design.global_ids(field), axis=0)  # (n,bag,k)
        sq_sum = sq_sum + jnp.sum(
            jnp.sum(wsq, axis=-1) * field.weights * field.weights, axis=-1
        )
    return 0.5 * (jnp.sum(phi_m * phi_m, axis=-1) - sq_sum)


def phi_ext(params: FMParams, x: Design, hp: FMHyperParams) -> jax.Array:
    """Φe (C, k+2): [Φ | φ_spec | 1]."""
    phi_m = design_matmul(x, params.w)
    spec = _self_pairwise(x, params.w, phi_m)
    if hp.use_linear:
        spec = spec + design_matmul(x, params.w_lin[:, None])[:, 0]
    if hp.use_bias:
        spec = spec + params.b
    ones = jnp.ones((x.n_rows,), jnp.float32)
    return jnp.concatenate([phi_m, spec[:, None], ones[:, None]], axis=1)


def psi_ext(params: FMParams, z: Design, hp: FMHyperParams) -> jax.Array:
    """Ψe (I, k+2): [Ψ | 1 | ψ_spec]."""
    psi_m = design_matmul(z, params.h)
    spec = _self_pairwise(z, params.h, psi_m)
    if hp.use_linear:
        spec = spec + design_matmul(z, params.h_lin[:, None])[:, 0]
    ones = jnp.ones((z.n_rows,), jnp.float32)
    return jnp.concatenate([psi_m, ones[:, None], spec[:, None]], axis=1)


def predict(params: FMParams, x: Design, z: Design, ctx, item, hp: FMHyperParams) -> jax.Array:
    pe, se = phi_ext(params, x, hp), psi_ext(params, z, hp)
    return jnp.sum(jnp.take(pe, ctx, axis=0) * jnp.take(se, item, axis=0), axis=-1)


def export_psi(params: FMParams, z: Design, hp: FMHyperParams) -> jax.Array:
    """ψ table for the retrieval engine: Ψe (n_items, k+2) with the FM
    column convention [Ψ | 1 | ψ_spec] — aligned so ⟨Φe, Ψe⟩ = ŷ (eq. 26)
    with Φe's [Φ | φ_spec | 1] ordering."""
    return psi_ext(params, z, hp)


def build_phi(params: FMParams, x: Design, hp: FMHyperParams,
              rows: Optional[jax.Array] = None) -> jax.Array:
    """φ rows for query contexts: Φe = [Φ | φ_spec | 1] (B, k+2) over
    ``rows`` of the context design ``x`` (rows are gathered BEFORE the
    matmuls — a query batch is O(B·k), not a full-design pass)."""
    return phi_ext(params, x if rows is None else take_rows(x, rows), hp)


def _embed_layer_update(
    table_col, self_ext, q, u, r_a, r_b, p2, p1, p0, j_ff, j_fs, j_ss,
    ids_g, xw, rows, vocab, offset, f, spec_col, hp, eta,
):
    """Vectorized Newton update of one embedding layer (field × dim f*).

    Patches the per-context caches but NOT the residual cache — the caller
    owns the e layout and applies (Δφ_{f*}, Δφ_spec) there (per layer on
    the flat path, one fused rank-(k_b+1) ``cd_resid_patch`` per block on
    the padded path)."""
    local = ids_g - offset
    w_rows = jnp.take(table_col, ids_g)                      # w_{l,f*} per entry
    g = jnp.take(sweeps.take_col(self_ext, f), rows) - xw * w_rows
    lp = segment_sum(xw * (jnp.take(q, rows) + g * jnp.take(u, rows)), local, vocab)
    lpp = segment_sum(
        xw * xw * (jnp.take(p2, rows) + 2 * g * jnp.take(p1, rows) + g * g * jnp.take(p0, rows)),
        local, vocab,
    )
    rp = segment_sum(xw * (jnp.take(r_a, rows) + g * jnp.take(r_b, rows)), local, vocab)
    rpp = segment_sum(xw * xw * (j_ff + 2 * g * j_fs + g * g * j_ss), local, vocab)
    w_layer = table_col[offset : offset + vocab]
    num = lp + hp.alpha0 * rp + hp.l2 * w_layer
    den = lpp + hp.alpha0 * rpp + hp.l2
    delta = -eta * num / jnp.maximum(den, 1e-12)
    table_col = table_col.at[offset : offset + vocab].add(delta)

    d_entry = xw * jnp.take(delta, local)                    # per-entry Δ(xw)
    n_rows = self_ext.shape[0]
    dphi_f = segment_sum(d_entry, rows, n_rows)              # Δφ_{f*}
    dphi_s = segment_sum(d_entry * g, rows, n_rows)          # Δφ_spec (linear patch)
    self_ext = sweeps.put_col(self_ext, f, sweeps.take_col(self_ext, f) + dphi_f)
    self_ext = self_ext.at[:, spec_col].add(dphi_s)
    q = q + dphi_f * p2 + dphi_s * p1
    u = u + dphi_f * p1 + dphi_s * p0
    r_a = r_a + dphi_f * j_ff + dphi_s * j_fs
    r_b = r_b + dphi_f * j_fs + dphi_s * j_ss
    return table_col, self_ext, q, u, r_a, r_b, dphi_f, dphi_s


def _side_sweep(
    table: jax.Array,
    lin: Optional[jax.Array],
    bias: Optional[jax.Array],
    self_ext: jax.Array,     # (n, k+2), kept in sync
    other_ext: jax.Array,    # (m, k+2), fixed
    other_j: jax.Array,      # (k+2, k+2) Gram of other_ext
    design: Design,
    rows_nnz: jax.Array,
    other_nnz_ids: jax.Array,
    alpha: jax.Array,
    e: jax.Array,
    spec_col: int,
    hp: FMHyperParams,
    schedule=None,
    sweep_index: int = 0,
):
    n_rows = design.n_rows
    layers = _field_layers(design, hp)
    o_spec_nnz = jnp.take(other_ext[:, spec_col], other_nnz_ids)  # ones, kept generic
    p0 = segment_sum(alpha * o_spec_nnz * o_spec_nnz, rows_nnz, n_rows)
    j_ss = other_j[spec_col, spec_col]

    # ---- embedding dims -------------------------------------------------
    def dim_body(f, carry):
        table, self_ext, e = carry
        other_f_nnz = jnp.take(sweeps.take_col(other_ext, f), other_nnz_ids)
        p2 = segment_sum(alpha * other_f_nnz * other_f_nnz, rows_nnz, n_rows)
        p1 = segment_sum(alpha * other_f_nnz * o_spec_nnz, rows_nnz, n_rows)
        q = segment_sum(alpha * e * other_f_nnz, rows_nnz, n_rows)
        u = segment_sum(alpha * e * o_spec_nnz, rows_nnz, n_rows)
        r_a = self_ext @ sweeps.take_col(other_j, f)
        r_b = self_ext @ other_j[:, spec_col]
        j_ff = other_j[f, f]
        j_fs = other_j[f, spec_col]
        table_col = sweeps.take_col(table, f)

        for ids_g, xw, rows, vocab, offset, eta in layers:
            table_col, self_ext, q, u, r_a, r_b, dphi_f, dphi_s = (
                _embed_layer_update(
                    table_col, self_ext, q, u, r_a, r_b, p2, p1, p0,
                    j_ff, j_fs, j_ss, ids_g, xw, rows, vocab, offset,
                    f, spec_col, hp, eta,
                )
            )
            e = (
                e
                + jnp.take(dphi_f, rows_nnz) * other_f_nnz
                + jnp.take(dphi_s, rows_nnz) * o_spec_nnz
            )
        return sweeps.put_col(table, f, table_col), self_ext, e

    table, self_ext, e = sweeps.sweep_columns(
        hp.k, dim_body, (table, self_ext, e),
        schedule=schedule, sweep_index=sweep_index,
    )

    # ---- linear weights --------------------------------------------------
    if hp.use_linear and lin is not None:
        u = segment_sum(alpha * e * o_spec_nnz, rows_nnz, n_rows)
        r_b = self_ext @ other_j[:, spec_col]
        for ids_g, xw, rows, vocab, offset, eta in layers:
            lin, self_ext, u, r_b, dspec = _linear_layer_update(
                lin, self_ext, u, r_b, p0, j_ss,
                ids_g, xw, rows, vocab, offset, spec_col, hp, eta,
            )
            e = e + jnp.take(dspec, rows_nnz) * o_spec_nnz

    # ---- global bias (context side only) ----------------------------------
    if hp.use_bias and bias is not None:
        u = segment_sum(alpha * e * o_spec_nnz, rows_nnz, n_rows)
        r_b = self_ext @ other_j[:, spec_col]
        bias, self_ext, delta = _bias_update(
            bias, self_ext, u, r_b, p0, j_ss, n_rows, spec_col, hp
        )
        e = e + delta * o_spec_nnz

    return table, lin, bias, self_ext, e


def _linear_layer_update(
    lin, self_ext, u, r_b, p0, j_ss, ids_g, xw, rows, vocab, offset,
    spec_col, hp, eta,
):
    """Newton step of one linear-weight layer; e patch left to the caller."""
    n_rows = self_ext.shape[0]
    local = ids_g - offset
    lp = segment_sum(xw * jnp.take(u, rows), local, vocab)
    lpp = segment_sum(xw * xw * jnp.take(p0, rows), local, vocab)
    rp = segment_sum(xw * jnp.take(r_b, rows), local, vocab)
    rpp = j_ss * segment_sum(xw * xw, local, vocab)
    lin_layer = lin[offset : offset + vocab]
    num = lp + hp.alpha0 * rp + hp.l2_lin * lin_layer
    den = lpp + hp.alpha0 * rpp + hp.l2_lin
    delta = -eta * num / jnp.maximum(den, 1e-12)
    lin = lin.at[offset : offset + vocab].add(delta)
    dspec = segment_sum(xw * jnp.take(delta, local), rows, n_rows)
    self_ext = self_ext.at[:, spec_col].add(dspec)
    u = u + dspec * p0
    r_b = r_b + dspec * j_ss
    return lin, self_ext, u, r_b, dspec


def _bias_update(bias, self_ext, u, r_b, p0, j_ss, n_rows, spec_col, hp):
    """Global-bias Newton step; e patch left to the caller."""
    lp = jnp.sum(u)
    lpp = jnp.sum(p0)
    rp = jnp.sum(r_b)
    rpp = j_ss * n_rows
    delta = -hp.eta * (lp + hp.alpha0 * rp) / jnp.maximum(lpp + hp.alpha0 * rpp, 1e-12)
    bias = bias + delta
    self_ext = self_ext.at[:, spec_col].add(delta)
    return bias, self_ext, delta


def _side_sweep_padded(
    table: jax.Array,
    lin: Optional[jax.Array],
    bias: Optional[jax.Array],
    self_ext: jax.Array,     # (n, k+2), kept in sync
    other_ext: jax.Array,    # (m, k+2), fixed
    other_j: jax.Array,      # (k+2, k+2) Gram of other_ext
    design: Design,
    ids_pad: jax.Array,      # (n, d_pad) opposite-side row ids
    alpha_pad: jax.Array,    # (n, d_pad), 0 on padding
    e_pad: jax.Array,        # (n, d_pad) residual grid
    spec_col: int,
    hp: FMHyperParams,
    k_b: int,
):
    """Fused FM side sweep on the padded grid: per block one
    ``cd_slab_reduce`` over [ψ_{f0..f0+k_b} | ψ_spec] feeds all per-context
    caches (q, u, p2, p1 and the cross-dim coupling), the field-level
    Newton steps run in XLA, one rank-(k_b+1) ``cd_resid_patch`` closes the
    block. Same fixed point as :func:`_side_sweep` (parity-tested).

    Ψ routing: in-kernel gather by default — the `(n_other, kb+1)` slab
    ``[Ψ[:, blk] | ψ_spec]`` rides into the kernels with the id grid, so
    the `(n, kb+1, d_pad)` tile never exists in HBM; pre-gathered when
    ``hp.psi_dispatch='pregather'`` or the slab busts the VMEM budget."""
    n_rows = design.n_rows
    layers = _field_layers(design, hp)
    psi_spec_pad = jnp.take(other_ext[:, spec_col], ids_pad)   # (n, d_pad)
    p0 = jnp.sum(alpha_pad * psi_spec_pad * psi_spec_pad, axis=1)
    j_ss = other_j[spec_col, spec_col]
    use_gather, _ = vmem.resolve_cd_sweep_dispatch(
        ids_pad.shape[1], k_b + 1, other_ext.shape[0], n_rows=n_rows,
        hold_tile=True, prefer_gather=sweeps.resolve_psi_dispatch(hp.psi_dispatch),
    )

    # ---- embedding dims, blocked ----------------------------------------
    def block_body(f0, kb, carry):
        table, self_ext, e_pad = carry
        blk = slice(f0, f0 + kb)
        if use_gather:
            # ψ slab [Ψ[:, blk] | ψ_spec] (n_other, kb+1) — kernel gathers
            psi_tab = jnp.concatenate(
                [other_ext[:, blk], other_ext[:, spec_col:spec_col + 1]],
                axis=1,
            )
            q_slab, p_slab = cd_slab_reduce_gather(
                psi_tab, ids_pad, alpha_pad, e_pad
            )
        else:
            psi_blk = jnp.concatenate(
                [
                    jnp.moveaxis(jnp.take(other_ext[:, blk], ids_pad, axis=0), -1, 1),
                    psi_spec_pad[:, None, :],
                ],
                axis=1,
            )                                                  # (n, kb+1, d_pad)
            q_slab, p_slab = cd_slab_reduce(psi_blk, alpha_pad, e_pad)
        u = q_slab[:, -1]
        dphi_cols = []
        dphi_s_tot = jnp.zeros((n_rows,), jnp.float32)
        for j in range(kb):
            f = f0 + j
            q = q_slab[:, j]
            p2 = p_slab[:, j, j]
            p1 = p_slab[:, j, -1]
            r_a = self_ext @ other_j[:, f]
            r_b = self_ext @ other_j[:, spec_col]
            j_ff = other_j[f, f]
            j_fs = other_j[f, spec_col]
            table_col = table[:, f]
            dphi_f_tot = jnp.zeros((n_rows,), jnp.float32)
            dphi_s_dim = jnp.zeros((n_rows,), jnp.float32)
            for ids_g, xw, rows, vocab, offset, eta in layers:
                table_col, self_ext, q, u, r_a, r_b, dphi_f, dphi_s = (
                    _embed_layer_update(
                        table_col, self_ext, q, u, r_a, r_b, p2, p1, p0,
                        j_ff, j_fs, j_ss, ids_g, xw, rows, vocab, offset,
                        f, spec_col, hp, eta,
                    )
                )
                dphi_f_tot = dphi_f_tot + dphi_f
                dphi_s_dim = dphi_s_dim + dphi_s
            table = table.at[:, f].set(table_col)
            if j + 1 < kb:  # Δe = Δφ_j·ψ_j + Δφ_s·ψ_spec moves later q's
                q_slab = q_slab.at[:, j + 1:kb].add(
                    dphi_f_tot[:, None] * p_slab[:, j, j + 1:kb]
                    + dphi_s_dim[:, None] * p_slab[:, -1, j + 1:kb]
                )
            dphi_cols.append(dphi_f_tot)
            dphi_s_tot = dphi_s_tot + dphi_s_dim
        dphi_blk = jnp.stack(dphi_cols + [dphi_s_tot], axis=1)  # (n, kb+1)
        if use_gather:
            e_pad = cd_resid_patch_gather(psi_tab, ids_pad, e_pad, dphi_blk)
        else:
            e_pad = cd_resid_patch(psi_blk, e_pad, dphi_blk)
        return table, self_ext, e_pad

    table, self_ext, e_pad = sweeps.sweep_columns(
        hp.k, None, (table, self_ext, e_pad), block=k_b, block_body=block_body
    )

    # ---- linear weights --------------------------------------------------
    if hp.use_linear and lin is not None:
        u = jnp.sum(alpha_pad * e_pad * psi_spec_pad, axis=1)
        r_b = self_ext @ other_j[:, spec_col]
        for ids_g, xw, rows, vocab, offset, eta in layers:
            lin, self_ext, u, r_b, dspec = _linear_layer_update(
                lin, self_ext, u, r_b, p0, j_ss,
                ids_g, xw, rows, vocab, offset, spec_col, hp, eta,
            )
            e_pad = e_pad + dspec[:, None] * psi_spec_pad

    # ---- global bias (context side only) ----------------------------------
    if hp.use_bias and bias is not None:
        u = jnp.sum(alpha_pad * e_pad * psi_spec_pad, axis=1)
        r_b = self_ext @ other_j[:, spec_col]
        bias, self_ext, delta = _bias_update(
            bias, self_ext, u, r_b, p0, j_ss, n_rows, spec_col, hp
        )
        e_pad = e_pad + delta * psi_spec_pad

    return table, lin, bias, self_ext, e_pad


@partial(jax.jit, static_argnames=("hp", "schedule", "sweep_index"))
def epoch(
    params: FMParams,
    x: Design,
    z: Design,
    data: Interactions,
    e: jax.Array,
    hp: FMHyperParams,
    schedule=None,
    sweep_index: int = 0,
    weights: Optional[jax.Array] = None,
) -> Tuple[FMParams, jax.Array]:
    # weights (optional, (nnz,) ctx-major): per-interaction confidence folds
    # into α exactly; None traces the identical unweighted program.
    if weights is not None:
        data = dataclasses.replace(data, alpha=data.alpha * weights)
    b, w_lin, w, h_lin, h = params
    pe = phi_ext(params, x, hp)
    se = psi_ext(params, z, hp)

    j_i = gram(se, implementation=hp.implementation)
    w, w_lin, b, pe, e = _side_sweep(
        w, w_lin if hp.use_linear else None, b if hp.use_bias else None,
        pe, se, j_i, x, data.ctx, data.item, data.alpha, e,
        spec_col=hp.k, hp=hp, schedule=schedule, sweep_index=sweep_index,
    )

    j_c = gram(pe, implementation=hp.implementation)
    e_t = sweeps.to_item_major(e, data.t_perm)
    alpha_t = sweeps.to_item_major(data.alpha, data.t_perm)
    h, h_lin, _, se, e_t = _side_sweep(
        h, h_lin if hp.use_linear else None, None,
        se, pe, j_c, z, data.t_item, data.t_ctx, alpha_t, e_t,
        spec_col=hp.k + 1, hp=hp, schedule=schedule, sweep_index=sweep_index,
    )
    e = sweeps.to_ctx_major(e_t, data.t_perm)
    return FMParams(b, w_lin, w, h_lin, h), e


@partial(jax.jit, static_argnames=("hp",), donate_argnums=(4,))
def epoch_padded(
    params: FMParams,
    x: Design,
    z: Design,
    pdata: PaddedInteractions,
    e_pad: jax.Array,
    hp: FMHyperParams,
    weights: Optional[jax.Array] = None,
) -> Tuple[FMParams, jax.Array]:
    """Fused iCD epoch over the dual padded layout; carries the ctx-major
    padded residual grid. Same sweep order and fixed point as :func:`epoch`
    (parity-tested). ``weights`` folds into both padded α grids."""
    if weights is not None:
        pdata = reweight_padded(pdata, weights)
    b, w_lin, w, h_lin, h = params
    k_b = sweeps.resolve_block_k(hp.block_k, hp.k)
    pe = phi_ext(params, x, hp)
    se = psi_ext(params, z, hp)

    j_i = gram(se, implementation=hp.implementation)
    w, w_lin, b, pe, e_pad = _side_sweep_padded(
        w, w_lin if hp.use_linear else None, b if hp.use_bias else None,
        pe, se, j_i, x, pdata.item_ids, pdata.alpha_c, e_pad,
        spec_col=hp.k, hp=hp, k_b=k_b,
    )

    e_pad_i = transfer_ctx_to_item(pdata, e_pad)

    j_c = gram(pe, implementation=hp.implementation)
    h, h_lin, _, se, e_pad_i = _side_sweep_padded(
        h, h_lin if hp.use_linear else None, None,
        se, pe, j_c, z, pdata.ctx_ids, pdata.alpha_i, e_pad_i,
        spec_col=hp.k + 1, hp=hp, k_b=k_b,
    )
    e_pad = transfer_item_to_ctx(pdata, e_pad_i)
    return FMParams(b, w_lin, w, h_lin, h), e_pad


def residuals_padded(
    params: FMParams, x: Design, z: Design, data: Interactions,
    pdata: PaddedInteractions, hp: FMHyperParams,
) -> jax.Array:
    """ŷ−ȳ on the ctx-major padded grid (0 on padding)."""
    return scatter_ctx_major(pdata, residuals(params, x, z, data, hp))


def residuals(params: FMParams, x: Design, z: Design, data: Interactions,
              hp: FMHyperParams) -> jax.Array:
    return sweeps.residuals_from_factors(
        phi_ext(params, x, hp), psi_ext(params, z, hp), data.ctx, data.item, data.y
    )


def objective(params: FMParams, x: Design, z: Design, data: Interactions,
              hp: FMHyperParams) -> jax.Array:
    e = residuals(params, x, z, data, hp)
    sq = jnp.sum(params.w**2) + jnp.sum(params.h**2)
    sq_lin = jnp.sum(params.w_lin**2) + jnp.sum(params.h_lin**2)
    pe, se = phi_ext(params, x, hp), psi_ext(params, z, hp)
    # NOTE: φ_spec/ψ_spec are model components, not free parameters — only
    # the L2 on true parameters enters; the implicit R covers the rest.
    return implicit_objective(
        pe, se, e, data, hp.alpha0, 0.0, jnp.zeros(())
    ) + hp.l2 * sq + hp.l2_lin * sq_lin


def fit(params, x, z, data, hp, n_epochs, callback=None, refresh_residuals=True,
        schedule=None, weights=None):
    e = residuals(params, x, z, data, hp)
    for ep in range(n_epochs):
        if refresh_residuals and ep > 0:
            e = residuals(params, x, z, data, hp)  # bound multi-hot drift
        params, e = epoch(params, x, z, data, e, hp, schedule, ep, weights)
        if callback is not None:
            callback(ep, params)
    return params
