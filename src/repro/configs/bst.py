"""BST [arXiv:1905.06874] — 1 transformer block over a 20-item sequence."""
import dataclasses

from repro.configs.base import RECSYS_SHAPES, RecsysConfig

CONFIG = RecsysConfig(
    name="bst",
    kind="bst",
    embed_dim=32,
    seq_len=20,
    n_blocks=1,
    n_heads=8,
    mlp=(1024, 512, 256),
    item_vocab=5_000_000,
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG, embed_dim=16, seq_len=6, n_heads=4, mlp=(32, 16), item_vocab=100,
)

SHAPES = RECSYS_SHAPES
