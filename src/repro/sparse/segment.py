"""Segment reductions and EmbeddingBag built from JAX primitives.

``jax.ops.segment_sum`` is the TPU-native scatter-reduce; EmbeddingBag is a
ragged gather over a (vocab, dim) table followed by a segment reduce. These
are the hot primitives of both the iCD solver (column sweeps reduce over the
observed-interaction CSR) and multi-hot feature lookups.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def segment_sum(data: jax.Array, segment_ids: jax.Array, num_segments: int) -> jax.Array:
    return jax.ops.segment_sum(data, segment_ids, num_segments=num_segments)


def segment_mean(data: jax.Array, segment_ids: jax.Array, num_segments: int) -> jax.Array:
    total = jax.ops.segment_sum(data, segment_ids, num_segments=num_segments)
    counts = jax.ops.segment_sum(
        jnp.ones(segment_ids.shape, dtype=data.dtype), segment_ids, num_segments=num_segments
    )
    counts = jnp.maximum(counts, 1)
    if data.ndim > 1:
        counts = counts.reshape(counts.shape + (1,) * (data.ndim - 1))
    return total / counts


def segment_max(data: jax.Array, segment_ids: jax.Array, num_segments: int) -> jax.Array:
    return jax.ops.segment_max(data, segment_ids, num_segments=num_segments)


def embedding_bag(
    table: jax.Array,
    ids: jax.Array,
    rows: jax.Array,
    n_rows: int,
    weights: Optional[jax.Array] = None,
    combiner: str = "sum",
) -> jax.Array:
    """Ragged EmbeddingBag: ``out[r] = combine_{j: rows[j]==r} w_j * table[ids[j]]``.

    Args:
      table:   (vocab, dim) embedding table.
      ids:     (nnz,) int32 feature ids (gather indices into ``table``).
      rows:    (nnz,) int32 output row per lookup, sorted or not.
      n_rows:  static number of output rows (batch).
      weights: optional (nnz,) per-lookup weights.
      combiner: 'sum' | 'mean' | 'max'.

    Returns:
      (n_rows, dim).

    """
    gathered = jnp.take(table, ids, axis=0)
    if weights is not None:
        gathered = gathered * weights[:, None].astype(gathered.dtype)
    if combiner == "sum":
        return segment_sum(gathered, rows, n_rows)
    if combiner == "mean":
        return segment_mean(gathered, rows, n_rows)
    if combiner == "max":
        return segment_max(gathered, rows, n_rows)
    raise ValueError(f"unknown combiner {combiner!r}")


def multi_hot_lookup(
    table: jax.Array,
    ids: jax.Array,
    mask: Optional[jax.Array] = None,
    combiner: str = "sum",
) -> jax.Array:
    """Fixed-shape EmbeddingBag for padded multi-hot batches.

    Args:
      table: (vocab, dim).
      ids:   (batch, bag) int32, padded with arbitrary ids where masked.
      mask:  (batch, bag) bool/float — 1 for valid entries; None = all valid.
      combiner: 'sum' | 'mean'.

    Returns:
      (batch, dim).
    """
    gathered = jnp.take(table, ids, axis=0)  # (batch, bag, dim)
    if mask is not None:
        gathered = gathered * mask[..., None].astype(gathered.dtype)
    summed = jnp.sum(gathered, axis=1)
    if combiner == "sum":
        return summed
    if combiner == "mean":
        denom = (
            jnp.sum(mask.astype(gathered.dtype), axis=1, keepdims=True)
            if mask is not None
            else jnp.full((ids.shape[0], 1), ids.shape[1], dtype=gathered.dtype)
        )
        return summed / jnp.maximum(denom, 1)
    raise ValueError(f"unknown combiner {combiner!r}")
