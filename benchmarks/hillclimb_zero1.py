"""Hillclimb #2 — deepseek-67b × train_4k (most collective-bound cell).

Baseline (ZeRO-3/FSDP params + fp32 masters as live params):
    collective 1.30e+03 s (!), memory 5.1e+01 s, compute 1.15e+01 s.
    Diagnosis: parameters sharded over (data×model) are ALL-GATHERED per
    layer per microbatch — 16 microbatches × 95 layers re-gather the whole
    67B model 16× per step (measured per-layer·per-mb AG term).

Iteration 1 — ZeRO-1 + bf16 live params:
    live params bf16, sharded over model only (replicated over data);
    fp32 master + Adam moments inside the optimizer state, sharded over
    (data×model); one bf16 grad all-reduce + one param-delta all-gather
    per STEP instead of per layer·microbatch.
    Napkin: grads AR ≈ 2×(134 GB/16) ≈ 16.8 GB → 0.34 s; param gather
    ≈ 7.9 GB → 0.16 s; activation ARs ≈ 4·95·16·(4096·8192·2B)·2 ≈ 0.8 TB
    → ~16 s. Predicted total ≈ 17 s (≈75× better).

Run:  PYTHONPATH=src:. python -m benchmarks.hillclimb_zero1
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import dataclasses  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.launch import hlo_analysis, sharding as sh  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.sharding import named  # noqa: E402
from repro.models import transformer as T  # noqa: E402
from repro.optim import adamw  # noqa: E402
from repro.optim.mixed import mixed_precision  # noqa: E402
from repro.train.train_step import build_train_step, init_state  # noqa: E402

ARCH = "deepseek-67b"
B, S = 256, 4096
COMPONENTS = ("flops", "bytes", "all-gather", "all-reduce", "reduce-scatter",
              "all-to-all", "collective-permute")


def _vector(compiled):
    ca = compiled.cost_analysis() or {}
    cb = hlo_analysis.collective_bytes(compiled.as_text())
    cb.pop("_counts")
    return np.array([float(ca.get("flops", 0)), float(ca.get("bytes accessed", 0))]
                    + [cb[k] for k in COMPONENTS[2:]])


def compile_probe(mesh, n_layers, microbatches, zero1: bool, batch=None,
                  model_axis=16):
    cfg = dataclasses.replace(
        get_config(ARCH), n_layers=n_layers, scan_layers=False,
        num_microbatches=microbatches,
    )
    params_abs = jax.eval_shape(lambda: T.init_params(jax.random.PRNGKey(0), cfg))
    if zero1:
        params_abs = jax.tree_util.tree_map(
            lambda s: jax.ShapeDtypeStruct(s.shape, jnp.bfloat16), params_abs
        )
        opt = mixed_precision(adamw(1e-4))
    else:
        opt = adamw(1e-4)
    state_abs = jax.eval_shape(lambda p: init_state(p, opt), params_abs)
    fsdp_specs = sh.lm_param_specs(cfg, params_abs, model_axis=model_axis)
    if zero1:
        st_specs, _live = sh.zero1_state_specs(fsdp_specs)
    else:
        st_specs = sh.train_state_specs(fsdp_specs)
    step = build_train_step(
        lambda p, b: T.loss_fn(cfg, p, b["tokens"], b["targets"]),
        opt, num_microbatches=microbatches, unroll_microbatches=True,
    )
    bsz = batch or B
    batch_abs = {"tokens": jax.ShapeDtypeStruct((bsz, S), jnp.int32),
                 "targets": jax.ShapeDtypeStruct((bsz, S), jnp.int32)}
    from jax.sharding import PartitionSpec as P

    with mesh:
        compiled = jax.jit(
            step,
            in_shardings=(named(mesh, st_specs), named(mesh, sh.lm_batch_specs(mesh))),
            out_shardings=(named(mesh, st_specs),
                           named(mesh, {"loss": P(), "grad_norm": P()})),
        ).lower(state_abs, batch_abs).compile()
    return _vector(compiled)


def measure(zero1: bool, mesh, l_full=95, m_full=16, model_axis=16):
    from benchmarks.probe_common import combine
    t0 = time.time()
    u11 = compile_probe(mesh, 1, 1, zero1, model_axis=model_axis)
    u21 = compile_probe(mesh, 2, 1, zero1, model_axis=model_axis)
    u11h = compile_probe(mesh, 1, 1, zero1, batch=B // 2, model_axis=model_axis)
    u21h = compile_probe(mesh, 2, 1, zero1, batch=B // 2, model_axis=model_axis)
    u12 = compile_probe(mesh, 1, 2, zero1, model_axis=model_axis)
    full, split = combine(u11, u21, u11h, u21h, u12, l_full, m_full)
    comp = dict(zip(COMPONENTS, full.tolist()))
    comp["_split"] = split
    total_coll = sum(comp[k] for k in COMPONENTS[2:])
    return {
        "variant": "zero1+bf16" if zero1 else "baseline(zero3/fp32)",
        "compile_s": round(time.time() - t0, 1),
        "compute_s": comp["flops"] / hlo_analysis.PEAK_FLOPS,
        "memory_s": comp["bytes"] / hlo_analysis.HBM_BW,
        "collective_s": total_coll / hlo_analysis.LINK_BW,
        "collective_breakdown": {k: comp[k] for k in COMPONENTS[2:]},
        "per_layer_split": comp.get("_split"),
    }


def main():
    mesh = make_production_mesh(multi_pod=False)
    results = {"cell": f"{ARCH} × train_4k", "mesh": "16x16"}
    try:
        results["baseline_roofline"] = json.load(
            open(f"results/dryrun/{ARCH}__train_4k__sp.json"))["roofline"]
    except FileNotFoundError:
        pass
    results["iterations"] = []
    for zero1 in (False, True):
        r = measure(zero1, mesh)
        results["iterations"].append(r)
        print(f"{r['variant']}: compute={r['compute_s']:.3e}s "
              f"memory={r['memory_s']:.3e}s coll={r['collective_s']:.3e}s",
              flush=True)

    # iteration 3: TP=4 / DP=64 — per-device batch 4× larger, activation
    # AR payloads ∝ B_loc shrink 4×, and kv heads (8) now divide the model
    # axis ⇒ column-parallel kv (no kv partial-sum ARs). Napkin: coll ≈ /4.
    mesh4 = jax.make_mesh((64, 4), ("data", "model"))
    r = measure(False, mesh4, model_axis=4)
    r["variant"] = "TP=4/DP=64 remesh"
    results["iterations"].append(r)
    print(f"{r['variant']}: compute={r['compute_s']:.3e}s "
          f"memory={r['memory_s']:.3e}s coll={r['collective_s']:.3e}s", flush=True)
    os.makedirs("results/perf", exist_ok=True)
    with open("results/perf/hillclimb_zero1.json", "w") as f:
        json.dump(results, f, indent=1, default=float)


if __name__ == "__main__":
    main()
