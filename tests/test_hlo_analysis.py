"""HLO analysis: shape/byte parsing, replica groups, collective accounting,
and the documented XLA while-body undercount that motivates calibration."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_analysis import (
    Roofline,
    _group_size,
    _shape_bytes,
    collective_bytes,
    roofline_from_compiled,
)


def test_shape_bytes():
    assert _shape_bytes("f32", "16,16") == 1024
    assert _shape_bytes("bf16", "8") == 16
    assert _shape_bytes("pred", "100") == 100
    assert _shape_bytes("s32", "") == 4  # scalar
    assert _shape_bytes("weird", "4") == 0


def test_group_size_formats():
    assert _group_size("replica_groups=[32,8]<=[256]") == 8
    assert _group_size("replica_groups={{0,1,2,3},{4,5,6,7}}") == 4
    assert _group_size("no groups here") == 1


def test_collective_bytes_synthetic():
    hlo = """
  %x = f32[256,1024]{1,0} all-reduce(%a), replica_groups=[16,16]<=[256]
  %y = bf16[512]{0} all-gather(%b), replica_groups={{0,1}}
  %z = f32[8,16]{1,0} all-to-all(%c), replica_groups={{0,1,2,3}}
  %not_a_collective = f32[9999999]{0} add(%p, %q)
  %fusion.1 = f32[4]{0} fusion(%x), calls=%all_reduce_like_name
"""
    out = collective_bytes(hlo)
    assert out["all-reduce"] == 2.0 * 256 * 1024 * 4          # 2× ring
    assert out["all-gather"] == 512 * 2
    assert out["all-to-all"] == 8 * 16 * 4 * 4                # slice × group
    assert out["collective-permute"] == 0.0
    assert out["_counts"]["all-reduce"] == 1


def test_roofline_terms_and_dominant():
    r = Roofline(
        flops=197e12, bytes_accessed=819e9 * 2, coll_bytes=50e9 * 0.5,
        coll_breakdown={}, compute_s=1.0, memory_s=2.0, collective_s=0.5,
    )
    assert r.dominant == "memory"
    assert r.bound_s == 2.0
    np.testing.assert_allclose(r.fraction_of_roofline(), 0.5)


def test_xla_counts_while_bodies_once():
    """The measured behaviour that motivates launch/calibrate.py: flops of a
    scanned body do not scale with trip count."""

    def make(n):
        def f(x, ws):
            def body(c, w):
                return jnp.tanh(c @ w), None

            return jax.lax.scan(body, x, ws)[0]

        from repro.launch.hlo_analysis import normalize_cost_analysis

        return normalize_cost_analysis(
            jax.jit(f)
            .lower(jax.ShapeDtypeStruct((64, 64), jnp.float32),
                   jax.ShapeDtypeStruct((n, 64, 64), jnp.float32))
            .compile()
            .cost_analysis()
        )["flops"]

    assert make(2) == make(8)  # trip count 2 vs 8: identical ⇒ counted once


def test_roofline_from_compiled_smoke():
    def f(a, b):
        return a @ b

    compiled = (
        jax.jit(f)
        .lower(jax.ShapeDtypeStruct((128, 128), jnp.float32),
               jax.ShapeDtypeStruct((128, 128), jnp.float32))
        .compile()
    )
    r = roofline_from_compiled(compiled)
    assert r.flops > 0 and r.compute_s > 0
    assert r.coll_bytes == 0.0  # single device ⇒ no collectives
