"""Chaos suite for the fault-tolerant serving mesh (serve/mesh.py):
replica kills mid-traffic, failover parity, graceful degradation with the
coverage/dead-range contract, deadline-bounded retries, health-checked
latency failover, re-placement, and the canary staged-publish protocol.

Every failure is driven through the injectable FaultInjector and simulated
clocks — deterministic chaos, no real processes harmed."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _zoo import _rand

from repro.core.models import mf
from repro.kernels.topk_score import topk_score_ref
from repro.serve.batcher import MicroBatcher
from repro.serve.cluster import dead_item_ranges, shard_psi
from repro.serve.engine import exclude_ids_from_lists, exclude_mask_from_lists
from repro.serve.mesh import (
    FaultInjector,
    FaultTolerantRetrievalMesh,
    ReplicaSet,
    RetryPolicy,
    ShardHealthMonitor,
)
from repro.serve.publish import StagedRollout


def _mesh(phi, psi, *, n_shards=4, n_replicas=2, k=13, injector=None,
          retry=None, **kw):
    mesh = FaultTolerantRetrievalMesh(
        lambda p=phi: p, n_shards=n_shards, n_replicas=n_replicas, k=k,
        block_items=32, injector=injector or FaultInjector(),
        retry=retry or RetryPolicy(max_attempts=3, backoff_base=1e-4),
        **kw,
    )
    mesh.publish(psi)
    return mesh


def test_kill_each_replica_in_turn_bit_identical():
    """THE acceptance criterion: with R=2, killing each replica in turn
    mid-traffic leaves every answer bit-identical (ids AND scores) to the
    healthy cluster / dense oracle — failover is invisible in results."""
    phi, psi = _rand((9, 16), 0), _rand((101, 16), 1)
    rs_ref, ri_ref = topk_score_ref(phi, psi, 13)
    inj = FaultInjector()
    mesh = _mesh(phi, psi, injector=inj)
    healthy_s, healthy_i = mesh.topk()
    np.testing.assert_array_equal(np.asarray(healthy_i), np.asarray(ri_ref))
    for s in range(4):
        for r in range(2):
            before = inj.triggered
            inj.fail(s, r, "error")
            # two queries: round-robin guarantees the killed replica is
            # routed to exactly once mid-traffic, whatever the rr phase
            for _ in range(2):
                res = mesh.topk()
                assert res.coverage == 1.0 and res.dead_ranges == ()
                np.testing.assert_array_equal(
                    np.asarray(res.ids), np.asarray(healthy_i)
                )
                assert bool(
                    (np.asarray(res.scores) == np.asarray(healthy_s)).all()
                ), f"scores not bit-identical after killing replica ({s},{r})"
            assert inj.triggered == before + 1  # the kill really was hit
            inj.heal(s, r)
            mesh.replica_set.mark_live(s, r)  # replica restarts before next
    assert mesh.stats["faults"] == 8 and mesh.stats["failovers"] == 8


def test_unreplicated_shard_kill_degrades_with_coverage_and_ranges():
    """R=1 and a shard killed: the query COMPLETES over the survivors and
    reports coverage < 1 plus the exact dead row range; surviving ids are
    bit-identical to the oracle restricted to surviving ranges."""
    phi, psi = _rand((7, 16), 2), _rand((101, 16), 3)
    inj = FaultInjector()
    mesh = _mesh(phi, psi, n_replicas=1, k=30, injector=inj)
    inj.fail(2, 0, "error")
    res = mesh.topk()
    table = mesh.table
    lo, hi = 2 * table.rows_per, min(3 * table.rows_per, 101)
    assert res.degraded and res.dead_ranges == ((lo, hi),)
    assert res.coverage == pytest.approx(1.0 - (hi - lo) / 101)
    # survivors: oracle over the catalogue with the dead range masked out
    mask = np.zeros((7, 101), bool)
    mask[:, lo:hi] = True
    rs_ref, ri_ref = topk_score_ref(phi, psi, 30, jnp.asarray(mask))
    np.testing.assert_array_equal(np.asarray(res.ids), np.asarray(ri_ref))
    got_s, ref_s = np.asarray(res.scores), np.asarray(rs_ref)
    finite = np.isfinite(ref_s)
    assert bool((got_s[finite] == ref_s[finite]).all())
    assert not np.isin(np.asarray(res.ids), np.arange(lo, hi)).any()
    # every shard dead: still completes, loudly all-empty
    for s in range(4):
        inj.fail(s, 0, "error")
    res2 = mesh.topk()
    assert res2.coverage == 0.0
    assert bool((np.asarray(res2.ids) == -1).all())
    assert bool(np.isneginf(np.asarray(res2.scores)).all())
    assert res2.dead_ranges == ((0, 101),)  # coalesced across shards


def test_retry_backoff_respects_deadline_budget():
    """Retries must never blow the caller's latency contract: total
    backoff + burned fault latency stays inside the budget, and a retry
    that would not fit is abandoned (degrade, don't be late)."""
    phi, psi = _rand((4, 8), 4), _rand((40, 8), 5)
    inj = FaultInjector()
    budget = 5e-3
    mesh = _mesh(
        phi, psi, n_shards=2, n_replicas=1, injector=inj, k=9,
        retry=RetryPolicy(max_attempts=10, backoff_base=1e-3,
                          deadline=budget),
        fail_threshold=100,  # keep the replica alive: force the retry path
    )
    # transient: two failures then healthy — retries recover within budget
    inj.fail(0, 0, "error", count=2)
    res = mesh.topk()
    assert res.coverage == 1.0
    assert mesh.stats["backoff_slept_s"] <= budget
    rs_ref, ri_ref = topk_score_ref(phi, psi, 9)
    np.testing.assert_array_equal(np.asarray(res.ids), np.asarray(ri_ref))
    # sticky timeout burning budget: gives up inside the budget, degrades
    inj.heal()
    before = mesh.stats["backoff_slept_s"]
    inj.fail(1, 0, "timeout", latency=4e-3)
    res2 = mesh.topk()
    assert res2.degraded
    assert mesh.stats["deadline_gaveups"] >= 1
    # one 4ms burned fault leaves ~1ms: the backoff must NOT be slept
    assert mesh.stats["backoff_slept_s"] - before < 1e-3


def test_mesh_budget_never_exceeds_batcher_max_delay():
    """The batcher wiring: retry deadline = max_delay ⇒ worst-case added
    service delay (faults + backoffs) stays within the flush contract."""
    phi, psi = _rand((6, 8), 6), _rand((40, 8), 7)
    inj = FaultInjector()
    max_delay = 2e-3
    mesh = _mesh(
        phi, psi, n_shards=2, n_replicas=2, injector=inj, k=9,
        retry=RetryPolicy(max_attempts=5, backoff_base=1e-3,
                          deadline=max_delay),
    )
    batcher = MicroBatcher(
        lambda phi_rows, eids: mesh.topk_phi(phi_rows, exclude_ids=eids),
        max_batch=4, max_delay=max_delay,
        clock=lambda: 0.0, version_fn=lambda: mesh.version,
    )
    inj.fail(0, 0, "timeout", latency=1.5e-3)
    inj.fail(0, 1, "timeout", latency=1.5e-3)
    tickets = [batcher.submit(np.asarray(phi)[r]) for r in range(4)]
    leftovers = batcher.drain()
    spent = mesh.stats["backoff_slept_s"]
    assert spent <= max_delay, (
        f"retry backoff {spent} blew the batcher max_delay {max_delay}"
    )
    # both replicas of shard 0 burned the budget: per-request degradation
    # is reported on the tickets rather than a blown deadline
    for t in tickets:
        got = leftovers.get(t) or batcher.result(t)
        assert got is not None
    assert mesh.stats["deadline_gaveups"] >= 1


def test_latency_straggler_flagged_and_routed_around():
    """Health-checked failover: a replica that answers but SLOWLY gets
    flagged by the latency watchdog and marked dead — subsequent traffic
    routes around it with parity intact."""
    phi, psi = _rand((5, 8), 8), _rand((60, 8), 9)
    clock = {"t": 0.0, "step": 1e-4}
    slow = {(1, 0): 5e-2}  # the scripted straggler: 500x the fleet

    def fake_clock():
        clock["t"] += clock["step"]
        return clock["t"]

    monitor = ShardHealthMonitor(threshold=3.0, patience=2, window=8)
    mesh = _mesh(phi, psi, n_shards=2, n_replicas=2, k=9, clock=fake_clock,
                 monitor=monitor)
    rs_ref, ri_ref = topk_score_ref(phi, psi, 9)
    reaped = []
    for _round in range(8):
        res = mesh.topk()
        np.testing.assert_array_equal(np.asarray(res.ids), np.asarray(ri_ref))
        # layer the scripted straggler profile on the real observations
        for (s, r), lat in slow.items():
            monitor.observe((s, r), lat)
        reaped = mesh.apply_health_check()
        if reaped:
            break
    assert (1, 0) in [tuple(k) for k in reaped]
    live_idx = {r.idx for r in mesh.replica_set.live(1)}
    assert 0 not in live_idx  # routed around
    res = mesh.topk()
    assert res.coverage == 1.0
    np.testing.assert_array_equal(np.asarray(res.ids), np.asarray(ri_ref))


def test_heal_replaces_orphaned_range_on_surviving_devices():
    """Re-placement: after a shard loses replicas, heal() rebuilds them
    from the authoritative copy (ElasticMeshManager recovery shape) and
    full-coverage serving resumes."""
    phi, psi = _rand((5, 8), 10), _rand((60, 8), 11)
    inj = FaultInjector()
    devices = list(jax.devices()) * 2  # degenerate single-host placement
    mesh = _mesh(phi, psi, n_shards=3, n_replicas=2, k=9, injector=inj,
                 devices=devices)
    inj.fail(1, 0, "error")
    inj.fail(1, 1, "error")
    res = mesh.topk()
    assert res.degraded
    inj.heal()
    placed = mesh.heal()
    assert len(placed) == 2 and all(s == 1 for s, _ in placed)
    assert len(mesh.replica_set.live(1)) == 2
    res2 = mesh.topk()
    assert res2.coverage == 1.0 and res2.dead_ranges == ()
    rs_ref, ri_ref = topk_score_ref(phi, psi, 9)
    np.testing.assert_array_equal(np.asarray(res2.ids), np.asarray(ri_ref))
    assert mesh.stats["replicas_replaced"] == 2


def test_auto_heal_restores_replication_after_kill():
    phi, psi = _rand((4, 8), 12), _rand((40, 8), 13)
    inj = FaultInjector()
    mesh = _mesh(phi, psi, n_shards=2, n_replicas=2, k=9, injector=inj,
                 auto_heal=True)
    inj.fail(0, 0, "error", count=1)  # transient: one dispatch fails
    res = mesh.topk()
    assert res.coverage == 1.0
    assert len(mesh.replica_set.live(0)) == 2  # healed back to target R


def test_stale_replica_refused_and_routed_around():
    """A replica stuck on an old table version must not answer: its
    dispatch is refused pre-kernel and traffic fails over."""
    phi, psi = _rand((5, 8), 14), _rand((40, 8), 15)
    inj = FaultInjector()
    mesh = _mesh(phi, psi, n_shards=2, n_replicas=2, k=9, injector=inj)
    inj.fail(1, 0, "stale")
    res = mesh.topk()
    assert res.coverage == 1.0
    rs_ref, ri_ref = topk_score_ref(phi, psi, 9)
    np.testing.assert_array_equal(np.asarray(res.ids), np.asarray(ri_ref))
    dead = [r for r in mesh.replica_set.replicas[1] if not r.alive]
    assert any(r.dead_reason == "StaleReplicaError" for r in dead)


def test_routing_policies_spread_and_prefer_idle():
    phi, psi = _rand((4, 8), 16), _rand((40, 8), 17)
    mesh = _mesh(phi, psi, n_shards=2, n_replicas=2, k=9)
    for _ in range(4):
        mesh.topk()
    served = [rep.served for rep in mesh.replica_set.replicas[0]]
    assert served == [2, 2]  # round-robin splits evenly
    # least_outstanding: a busy replica is avoided
    rs = ReplicaSet(shard_psi(psi, 2), 2, policy="least_outstanding")
    rs.replicas[0][0].outstanding = 5
    assert rs.pick(0).idx == 1
    rs.replicas[0][0].outstanding = 0
    assert rs.pick(0).idx == 0  # idx tiebreak


def test_replica_set_places_copies_on_distinct_devices():
    """The (s + r) % D rotation: copies of one shard must land on
    different devices whenever R <= D."""
    psi = _rand((40, 8), 18)

    class FakeDev:  # placement bookkeeping only — never dispatched to
        def __init__(self, i):
            self.i = i

        def __repr__(self):
            return f"dev{self.i}"

    devices = [FakeDev(i) for i in range(4)]
    table = shard_psi(psi, 4)
    # avoid jax.device_put on fakes: check the placement map only
    rs = ReplicaSet.__new__(ReplicaSet)
    rs.table, rs.n_replicas, rs.devices = table, 2, devices
    for s in range(4):
        assert rs._device_for(s, 0).i != rs._device_for(s, 1).i


def test_staged_rollout_promotes_good_and_rolls_back_bad():
    """The drain-and-restart rollout: a good table promotes after the
    mirrored health check; a bad table (NaN ψ) rolls back with the live
    version untouched and never serves a query."""
    phi, psi = _rand((6, 8), 19), _rand((40, 8), 20)
    mesh = _mesh(phi, psi, n_shards=2, n_replicas=2, k=9)
    assert mesh.version == 1
    rollout = StagedRollout(mesh, mirror_phi=phi)
    ok, report = rollout.publish(psi * 0.5)  # same ranking, scaled scores
    assert ok and mesh.version == 2 and report["promoted_version"] == 2
    res = mesh.topk()
    rs_ref, ri_ref = topk_score_ref(phi, psi * 0.5, 9)
    np.testing.assert_array_equal(np.asarray(res.ids), np.asarray(ri_ref))
    # bad table: NaN scores fail the mirror check, version stays 2
    bad = jnp.asarray(np.full((40, 8), np.nan), jnp.float32)
    ok2, report2 = rollout.publish(bad)
    assert not ok2 and not report2["checks"]["scores_finite"]
    assert mesh.version == 2
    res2 = mesh.topk()  # still serving the promoted good table
    np.testing.assert_array_equal(np.asarray(res2.ids), np.asarray(ri_ref))
    assert not any(r.canary for row in mesh.replica_set.replicas for r in row)
    assert [h[1] for h in rollout.history] == [True, False]
    # a caller validate policy can also veto (e.g. rank-overlap floor)
    shuffled = np.asarray(psi)[::-1].copy()  # permuted ids: ranking changes
    ok3, _ = StagedRollout(
        mesh, mirror_phi=phi,
        validate=lambda live, canary: bool(
            (np.asarray(live.ids) == np.asarray(canary.ids)).all()
        ),
    ).publish(jnp.asarray(shuffled))
    assert not ok3 and mesh.version == 2


def test_canary_double_stage_and_misuse_raise():
    phi, psi = _rand((4, 8), 21), _rand((40, 8), 22)
    mesh = _mesh(phi, psi, n_shards=2, n_replicas=2, k=9)
    with pytest.raises(RuntimeError, match="no canary"):
        mesh.promote_canary()
    mesh.begin_canary(psi)
    with pytest.raises(RuntimeError, match="already staged"):
        mesh.begin_canary(psi)
    mesh.rollback_canary()
    with pytest.raises(RuntimeError, match="no canary"):
        mesh.rollback_canary()


def test_degraded_tickets_carry_coverage_through_batcher():
    """The batcher surfaces the degradation contract per ticket, and
    degraded answers are never cached (a heal must be visible)."""
    n_ctx, n_items = 30, 77
    params = mf.init(jax.random.PRNGKey(1), n_ctx, n_items, 8)
    inj = FaultInjector()
    mesh = FaultTolerantRetrievalMesh(
        lambda ctx: mf.build_phi(params, ctx), n_shards=2, n_replicas=1,
        k=10, block_items=32, injector=inj,
        retry=RetryPolicy(max_attempts=2, backoff_base=1e-4, deadline=1e-2),
    )
    mesh.publish(mf.export_psi(params))
    clock = {"t": 0.0}
    batcher = MicroBatcher(
        lambda phi, eids: mesh.topk_phi(phi, exclude_ids=eids),
        max_batch=4, max_delay=1.0, clock=lambda: clock["t"],
        version_fn=lambda: mesh.version,
    )
    phi_all = np.asarray(mf.build_phi(params, jnp.arange(n_ctx)))
    inj.fail(1, 0, "error")
    t1 = batcher.submit(phi_all[5], key=("user", 5))
    batcher.flush()
    res = batcher.result(t1)
    scores, ids = res  # tuple-compat intact
    table = mesh.table
    lo, hi = table.rows_per, min(2 * table.rows_per, n_items)
    assert res.degraded and res.dead_ranges == ((lo, hi),)
    assert batcher.stats["degraded_results"] == 1
    assert len(batcher._cache) == 0  # degraded: NOT cached
    # heal; the same key must now be recomputed at full coverage
    inj.heal()
    mesh.replica_set.mark_live(1, 0)
    t2 = batcher.submit(phi_all[5], key=("user", 5))
    assert batcher.stats["cache_hits"] == 0
    batcher.flush()
    res2 = batcher.result(t2)
    assert res2.coverage == 1.0
    rs_ref, ri_ref = topk_score_ref(
        phi_all[5:6], np.asarray(mf.export_psi(params)), 10
    )
    np.testing.assert_array_equal(res2.ids, np.asarray(ri_ref)[0])


def test_degraded_coverage_reported_through_sharded_eval():
    """eval/ranking.py's sharded path labels metrics computed against a
    partially-dead catalogue instead of reporting them as full."""
    from repro.eval.ranking import ranking_eval

    rng = np.random.default_rng(23)
    n_eval, n_items = 24, 60
    params = mf.init(jax.random.PRNGKey(2), n_eval, n_items, 8)
    inj = FaultInjector()
    mesh = FaultTolerantRetrievalMesh(
        lambda ctx: mf.build_phi(params, ctx), n_shards=3, n_replicas=1,
        k=10, block_items=32, injector=inj,
        retry=RetryPolicy(max_attempts=2, backoff_base=1e-4),
    )
    mesh.publish(mf.export_psi(params))
    phi = mf.build_phi(params, jnp.arange(n_eval))
    truth = rng.integers(0, n_items, size=n_eval)
    res_full = ranking_eval(phi, None, truth, k=10, batch_rows=8,
                            cluster=mesh)
    assert res_full["coverage"] == 1.0 and res_full["dead_ranges"] == ()
    inj.fail(0, 0, "error")
    res_deg = ranking_eval(phi, None, truth, k=10, batch_rows=8,
                           cluster=mesh)
    table = mesh.table
    assert res_deg["coverage"] < 1.0
    assert res_deg["dead_ranges"] == ((0, table.rows_per),)


def test_exclusion_rides_through_failover():
    """Per-row exclude-id lists keep filtering correctly when a replica
    dies mid-traffic (global ids are replica-agnostic)."""
    rng = np.random.default_rng(24)
    phi, psi = _rand((6, 16), 25), _rand((101, 16), 26)
    inj = FaultInjector()
    mesh = _mesh(phi, psi, injector=inj, k=20)
    lists = [rng.choice(101, size=7, replace=False) for _ in range(6)]
    eids = exclude_ids_from_lists(lists)
    rs_ref, ri_ref = topk_score_ref(
        phi, psi, 20, exclude_mask_from_lists(lists, 101)
    )
    inj.fail(2, 0, "error")
    res = mesh.topk(exclude_ids=eids)
    assert res.coverage == 1.0
    np.testing.assert_array_equal(np.asarray(res.ids), np.asarray(ri_ref))


CHAOS_SUBPROCESS_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    os.environ["REPRO_PALLAS_INTERPRET"] = "1"
    import sys
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp, numpy as np

    from repro.kernels.topk_score import topk_score_ref
    from repro.serve.mesh import (FaultInjector, FaultTolerantRetrievalMesh,
                                  RetryPolicy)

    rng = np.random.default_rng(0)
    phi = jnp.asarray(rng.normal(size=(9, 16)), jnp.float32)
    psi = jnp.asarray(rng.normal(size=(101, 16)), jnp.float32)
    inj = FaultInjector()
    devices = jax.devices()
    assert len(devices) == 4
    mesh = FaultTolerantRetrievalMesh(
        lambda p=phi: p, n_shards=4, n_replicas=2, k=13, block_items=32,
        devices=devices, injector=inj,
        retry=RetryPolicy(max_attempts=3, backoff_base=1e-4),
    )
    mesh.publish(psi)
    # copies of each range really live on distinct devices
    for s in range(4):
        devs = {str(r.device) for r in mesh.replica_set.replicas[s]}
        assert len(devs) == 2, devs
    rs_ref, ri_ref = topk_score_ref(phi, psi, 13)
    res = mesh.topk()
    np.testing.assert_array_equal(np.asarray(res.ids), np.asarray(ri_ref))
    # kill every replica on device 0 (a whole host dying): arm faults for
    # any stray dispatch AND mark them dead (the detector's verdict)
    dev0 = devices[0]
    for s in range(4):
        for r in mesh.replica_set.replicas[s]:
            if r.device == dev0:
                inj.fail(s, r.idx, "error")
                mesh.replica_set.mark_dead(s, r.idx, reason="host-loss")
    res2 = mesh.topk()
    assert res2.coverage == 1.0
    np.testing.assert_array_equal(np.asarray(res2.ids), np.asarray(ri_ref))
    assert (np.asarray(res2.scores) == np.asarray(res.scores)).all()
    # heal re-places the dead capacity on the surviving devices only
    inj.heal()
    placed = mesh.heal()
    assert placed, "nothing re-placed"
    for s in range(4):
        for r in mesh.replica_set.live(s):
            assert str(r.device) != str(dev0)
    res3 = mesh.topk()
    np.testing.assert_array_equal(np.asarray(res3.ids), np.asarray(ri_ref))
    print("CHAOS-MESH-OK")
    """
)


@pytest.mark.slow
def test_multi_device_chaos_subprocess():
    """4 forced host devices (the PR-5 shard_map harness shape): R=2 over
    4 devices, kill one whole device's replicas, assert bit-identical
    survivors and heal-onto-survivors."""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-c", CHAOS_SUBPROCESS_SCRIPT],
        capture_output=True, text=True,
        cwd=os.path.dirname(os.path.dirname(__file__)),
        env={**env, "PYTHONPATH": "src"}, timeout=600,
    )
    assert "CHAOS-MESH-OK" in proc.stdout, (
        proc.stdout[-2000:] + proc.stderr[-3000:]
    )


def test_dead_item_ranges_coalesce_and_clip():
    table = shard_psi(_rand((10, 4), 27), 4)  # rows_per=3, last shard short
    assert dead_item_ranges(table, [1, 2]) == ((3, 9),)
    assert dead_item_ranges(table, [3]) == ((9, 10),)  # clipped to n_items
    assert dead_item_ranges(table, [0, 2]) == ((0, 3), (6, 9))
    assert dead_item_ranges(table, []) == ()
