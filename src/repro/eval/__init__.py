"""Offline evaluation harnesses (paper §6 protocols at serving scale)."""
from repro.eval.ranking import ranking_eval  # noqa: F401
