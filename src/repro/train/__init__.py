from repro.train.train_step import TrainState, build_train_step  # noqa: F401
from repro.train.trainer import Trainer  # noqa: F401
