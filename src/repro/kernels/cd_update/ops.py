"""Jit'd public wrapper for the fused CD column update."""
from functools import partial

import jax

from repro.kernels import use_interpret
from repro.kernels.cd_update.kernel import cd_column_update_pallas


@partial(jax.jit, static_argnames=("alpha0", "l2", "eta", "block_ctx"))
def cd_column_update(psi, alpha, e, w_col, r1, jff, *, alpha0, l2, eta=1.0,
                     block_ctx=256):
    return cd_column_update_pallas(
        psi, alpha, e, w_col, r1, jff,
        alpha0=alpha0, l2=l2, eta=eta, block_ctx=block_ctx,
        interpret=use_interpret(),
    )
