"""Observed-interaction container for implicit-feedback learning.

Holds the rescaled positive set ``S`` of Lemma 1 in COO-sorted-by-row layout
(plus the transposed layout for item-side sweeps). All arrays are fixed-shape
device arrays — the iCD solver jits over them.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Interactions:
    """Rescaled observed feedback S (Lemma 1, eq. 8) in dual COO layout.

    Context-major arrays (sorted by ``ctx``):
      ctx, item:  (nnz,) int32
      y:          (nnz,) f32 — rescaled targets ȳ = α/(α−α₀)·y
      alpha:      (nnz,) f32 — rescaled confidences ᾱ = α−α₀

    Item-major view of the same triplets (sorted by item):
      t_ctx, t_item, t_perm — ``t_perm`` maps item-major position → context-
      major position so residual caches can be permuted between sweeps.
    """

    ctx: jax.Array
    item: jax.Array
    y: jax.Array
    alpha: jax.Array
    t_ctx: jax.Array
    t_item: jax.Array
    t_perm: jax.Array
    n_ctx: int = dataclasses.field(metadata=dict(static=True))
    n_items: int = dataclasses.field(metadata=dict(static=True))

    @property
    def nnz(self) -> int:
        return int(self.ctx.shape[0])


def build_interactions(
    ctx: np.ndarray,
    item: np.ndarray,
    y: np.ndarray,
    alpha: np.ndarray,
    n_ctx: int,
    n_items: int,
    alpha0: float = 1.0,
    rescale: bool = True,
) -> Interactions:
    """Build the dual-layout container, applying the Lemma 1 rescaling.

    Args:
      ctx, item: observed (context, item) pairs.
      y, alpha: raw scores and confidences (α must exceed α₀).
      alpha0: the implicit confidence α₀ of the zero set S⁰.
      rescale: apply eq. (8); disable when the caller pre-rescaled.
    """
    ctx = np.asarray(ctx, dtype=np.int64)
    item = np.asarray(item, dtype=np.int64)
    y = np.asarray(y, dtype=np.float64)
    alpha = np.asarray(alpha, dtype=np.float64)
    if rescale:
        if np.any(alpha <= alpha0):
            raise ValueError("Lemma 1 rescaling needs alpha > alpha0 on S+")
        y = alpha / (alpha - alpha0) * y
        alpha = alpha - alpha0

    order = np.lexsort((item, ctx))
    ctx, item, y, alpha = ctx[order], item[order], y[order], alpha[order]

    t_order = np.lexsort((ctx, item))
    return Interactions(
        ctx=jnp.asarray(ctx, dtype=jnp.int32),
        item=jnp.asarray(item, dtype=jnp.int32),
        y=jnp.asarray(y, dtype=jnp.float32),
        alpha=jnp.asarray(alpha, dtype=jnp.float32),
        t_ctx=jnp.asarray(ctx[t_order], dtype=jnp.int32),
        t_item=jnp.asarray(item[t_order], dtype=jnp.int32),
        t_perm=jnp.asarray(t_order, dtype=jnp.int32),
        n_ctx=int(n_ctx),
        n_items=int(n_items),
    )
