"""iCD for PARAFAC tensor factorization (paper §5.3.1).

Model (eq. 34): ŷ(c1,c2,i) = Σ_f u_{c1,f} v_{c2,f} w_{i,f}, the 3-mode
extension of MF. k-separable with φ_f(c1,c2) = u_{c1,f}·v_{c2,f} and
ψ_f(i) = w_{i,f} (eq. 35). The regularizer derivatives (eqs. 37–38) reduce
to per-c1 reductions over that context's *partner* c2 values:

    R'(u_{c1*,f*})  = 2 Σ_f J_I(f,f*) u_{c1*,f} K_{c1*}(f,f*)
    R''(u_{c1*,f*}) = 2 J_I(f*,f*) K_{c1*}(f*,f*)
    K_{c1}(f,f*)    = Σ_{c2:(c1,c2)∈C} v_{c2,f} v_{c2,f*}

Context modes (paper's distinction):
  * ``sparse``  — C ⊂ C1×C2 is exactly the provided pair list; K is a
    segment-reduce over pairs. O((|C|+|I|)k²) per epoch.
  * ``dense``   — C = C1×C2; K decomposes to J_{C2} (eq. 39), identical for
    every c1, and J_C = J_{C1} ⊙ J_{C2} for the item sweep.
    O((|C1|+|C2|+|I|)k²) per epoch — no pair materialization.

The item sweep is exactly MF's (§5.1): "The item side is equivalent to
matrix factorization."
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core import sweeps
from repro.core.gram import gram
from repro.core.implicit import explicit_loss
from repro.sparse.interactions import Interactions
from repro.sparse.segment import segment_sum


class PARAFACParams(NamedTuple):
    u: jax.Array  # (n_c1, k)
    v: jax.Array  # (n_c2, k)
    w: jax.Array  # (n_items, k)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class TensorContext:
    """Observed context pairs C ⊆ C1×C2. ``Interactions.ctx`` indexes rows
    of this pair list."""

    c1: jax.Array  # (n_ctx,) int32
    c2: jax.Array  # (n_ctx,) int32
    n_c1: int = dataclasses.field(metadata=dict(static=True))
    n_c2: int = dataclasses.field(metadata=dict(static=True))

    @property
    def n_ctx(self) -> int:
        return int(self.c1.shape[0])


@dataclasses.dataclass(frozen=True)
class PARAFACHyperParams:
    k: int
    alpha0: float = 1.0
    l2: float = 0.1
    eta: float = 1.0
    dense_context: bool = False  # True ⇒ regularizer universe is C1×C2
    implementation: str = "xla"


def init(key, n_c1: int, n_c2: int, n_items: int, k: int, sigma: float = 0.1) -> PARAFACParams:
    k1, k2, k3 = jax.random.split(key, 3)
    return PARAFACParams(
        u=sigma * jax.random.normal(k1, (n_c1, k), jnp.float32),
        v=sigma * jax.random.normal(k2, (n_c2, k), jnp.float32),
        w=sigma * jax.random.normal(k3, (n_items, k), jnp.float32),
    )


def phi(params: PARAFACParams, tc: TensorContext) -> jax.Array:
    """Φ over the observed pair list (sparse-context materialization)."""
    return jnp.take(params.u, tc.c1, axis=0) * jnp.take(params.v, tc.c2, axis=0)


def psi(params: PARAFACParams) -> jax.Array:
    return params.w


def predict(params: PARAFACParams, c1, c2, item) -> jax.Array:
    return jnp.sum(
        jnp.take(params.u, c1, axis=0)
        * jnp.take(params.v, c2, axis=0)
        * jnp.take(params.w, item, axis=0),
        axis=-1,
    )


def _context_mode_sweep(
    side: jax.Array,          # (n_side, k): U (group by c1) or V (group by c2)
    partner: jax.Array,       # (n_partner, k): V or U
    group_of_pair: jax.Array,     # (n_ctx,) c1 or c2 per pair
    partner_of_pair: jax.Array,   # (n_ctx,) c2 or c1 per pair
    j_i: jax.Array,
    data: Interactions,
    w_items: jax.Array,
    e: jax.Array,
    n_side: int,
    hp: PARAFACHyperParams,
) -> Tuple[jax.Array, jax.Array]:
    """Sweep one context mode (U or V). Sparse-context K via segment sums;
    dense-context K via the partner Gram (eq. 39)."""
    pair_of_nnz = data.ctx

    def body(f, carry):
        side_m, e = carry
        s_col = sweeps.take_col(side_m, f)
        p_col_pair = jnp.take(sweeps.take_col(partner, f), partner_of_pair)  # (n_ctx,)
        w_col_nnz = jnp.take(sweeps.take_col(w_items, f), data.item)
        other_nnz = jnp.take(p_col_pair, pair_of_nnz) * w_col_nnz  # ∂ŷ per nnz

        grp_nnz = jnp.take(group_of_pair, pair_of_nnz)
        lp = segment_sum(data.alpha * e * other_nnz, grp_nnz, n_side)
        lpp = segment_sum(data.alpha * other_nnz * other_nnz, grp_nnz, n_side)

        if hp.dense_context:
            # K_{c1}(·,f*) = J_partner[:, f*] — identical for every group row.
            j_p_col = partner.T @ sweeps.take_col(partner, f)        # (k,)
            kmat = jnp.broadcast_to(j_p_col[None, :], side_m.shape)  # (n_side, k)
        else:
            pp = jnp.take(partner, partner_of_pair, axis=0)          # (n_ctx, k)
            kmat = segment_sum(pp * p_col_pair[:, None], group_of_pair, n_side)
        rp = jnp.sum(kmat * side_m * sweeps.take_col(j_i, f)[None, :], axis=1)
        rpp = j_i[f, f] * sweeps.take_col(kmat, f)

        delta = sweeps.newton_delta(
            sweeps.NewtonParts(lp + hp.alpha0 * rp, lpp + hp.alpha0 * rpp),
            s_col, hp.l2, hp.eta,
        )
        e = e + jnp.take(delta, grp_nnz) * other_nnz
        return sweeps.put_col(side_m, f, s_col + delta), e

    return jax.lax.fori_loop(0, hp.k, body, (side, e))


def _item_sweep(params_w, j_c, phi_cols_nnz, data, e_t, alpha_t, hp):
    """MF item sweep (paper: identical to §5.1)."""

    def body(f, carry):
        w_m, e_t = carry
        o_col = phi_cols_nnz(f)
        w_col = sweeps.take_col(w_m, f)
        lp = segment_sum(alpha_t * e_t * o_col, data.t_item, data.n_items)
        lpp = segment_sum(alpha_t * o_col * o_col, data.t_item, data.n_items)
        rp = w_m @ sweeps.take_col(j_c, f)
        rpp = j_c[f, f]
        delta = sweeps.newton_delta(
            sweeps.NewtonParts(lp + hp.alpha0 * rp, lpp + hp.alpha0 * rpp),
            w_col, hp.l2, hp.eta,
        )
        e_t = e_t + jnp.take(delta, data.t_item) * o_col
        return sweeps.put_col(w_m, f, w_col + delta), e_t

    return jax.lax.fori_loop(0, hp.k, body, (params_w, e_t))


@partial(jax.jit, static_argnames=("hp",))
def epoch(
    params: PARAFACParams,
    tc: TensorContext,
    data: Interactions,
    e: jax.Array,
    hp: PARAFACHyperParams,
) -> Tuple[PARAFACParams, jax.Array]:
    """One iCD epoch: U sweep → V sweep → item (W) sweep."""
    u, v, w = params
    j_i = gram(w, implementation=hp.implementation)

    u, e = _context_mode_sweep(
        u, v, tc.c1, tc.c2, j_i, data, w, e, u.shape[0], hp
    )
    v, e = _context_mode_sweep(
        v, u, tc.c2, tc.c1, j_i, data, w, e, v.shape[0], hp
    )

    if hp.dense_context:
        j_c = gram(u) * gram(v)  # eq. (39): J_C = J_{C1} ⊙ J_{C2}
    else:
        j_c = gram(jnp.take(u, tc.c1, axis=0) * jnp.take(v, tc.c2, axis=0))
    e_t = sweeps.to_item_major(e, data.t_perm)
    alpha_t = sweeps.to_item_major(data.alpha, data.t_perm)
    phi_cols = lambda f: jnp.take(
        jnp.take(sweeps.take_col(u, f), tc.c1) * jnp.take(sweeps.take_col(v, f), tc.c2),
        data.t_ctx,
    )
    w, e_t = _item_sweep(w, j_c, phi_cols, data, e_t, alpha_t, hp)
    e = sweeps.to_ctx_major(e_t, data.t_perm)
    return PARAFACParams(u, v, w), e


def residuals(params: PARAFACParams, tc: TensorContext, data: Interactions) -> jax.Array:
    return sweeps.residuals_from_factors(
        phi(params, tc), params.w, data.ctx, data.item, data.y
    )


def objective(params: PARAFACParams, tc: TensorContext, data: Interactions, hp: PARAFACHyperParams) -> jax.Array:
    e = residuals(params, tc, data)
    if hp.dense_context:
        reg = jnp.sum(gram(params.u) * gram(params.v) * gram(params.w))
    else:
        reg = jnp.sum(gram(phi(params, tc)) * gram(params.w))
    sq = sum(jnp.sum(p**2) for p in params)
    return explicit_loss(e, data.alpha) + hp.alpha0 * reg + hp.l2 * sq


def fit(params, tc, data, hp, n_epochs, callback=None):
    e = residuals(params, tc, data)
    for ep in range(n_epochs):
        params, e = epoch(params, tc, data, e, hp)
        if callback is not None:
            callback(ep, params)
    return params
