"""repro — production multi-pod JAX framework for the iCD paper.

Implements "A Generic Coordinate Descent Framework for Learning from
Implicit Feedback" (Bayer, Kanagal, He, Rendle, 2016) as a first-class
feature of a framework-scale training/inference system:

- ``repro.core``       — k-separable models, implicit regularizer, iCD solver
- ``repro.sparse``     — CSR / segment ops / EmbeddingBag / neighbor sampler
- ``repro.models``     — architecture zoo (LM transformers, recsys, GNN)
- ``repro.kernels``    — Pallas TPU kernels (gram, embedding_bag, cd_update,
                         flash_attention) with pure-jnp oracles
- ``repro.optim``      — optimizers, schedules, gradient compression
- ``repro.train``      — train-step builders, remat, microbatching
- ``repro.serve``      — decode / recsys serving paths
- ``repro.checkpoint`` — fault-tolerant sharded checkpointing
- ``repro.runtime``    — elastic mesh management, straggler watchdog
- ``repro.configs``    — assigned architecture configs + the paper's own
- ``repro.launch``     — production meshes, multi-pod dry-run, drivers
"""

__version__ = "1.0.0"
