"""Sharding rules: optimizer/train-state PartitionSpecs and the iCD specs.

Conventions (DESIGN.md §5):
  * batch/context dims shard over ``dp`` = ("pod","data") on multi-pod,
    ("data",) on single-pod;
  * weights shard over "model" on their parallel dim and over "data" on the
    other large dim (ZeRO/FSDP via GSPMD all-gather-at-use). Parameters are
    intentionally NOT sharded over "pod": cross-pod traffic is the gradient
    all-reduce only;
  * embedding / vocab tables row-shard over "model";
  * small vectors (norms, biases) replicate.
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import dp_axes


def named(mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda spec: NamedSharding(mesh, spec),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def _drop_data(spec: P) -> P:
    """Replace every 'data'/('data',) entry with None (ZeRO-1 live params:
    replicated over data, sharded over model only)."""
    def clean(e):
        if e == "data" or e == ("data",):
            return None
        return e

    return P(*[clean(e) for e in spec])


# ------------------------------------------------------------- optimizer --
def opt_state_specs(param_specs):
    """AdamW state: m/v mirror the parameters, step replicates."""
    return {"step": P(), "m": param_specs, "v": param_specs}


def train_state_specs(param_specs):
    from repro.train.train_step import TrainState

    return TrainState(params=param_specs, opt=opt_state_specs(param_specs),
                      step=P())


def zero1_state_specs(fsdp_param_specs):
    """ZeRO-1 TrainState specs: live (bf16) params lose the 'data' axis;
    the fp32 master + adam moments inside the optimizer keep it."""
    from repro.train.train_step import TrainState

    live = jax.tree_util.tree_map(
        _drop_data, fsdp_param_specs,
        is_leaf=lambda x: isinstance(x, P),
    )
    opt = {"master": fsdp_param_specs,
           "inner": opt_state_specs(fsdp_param_specs)}
    return TrainState(params=live, opt=opt, step=P()), live


# ------------------------------------------------------------------ icd ---
def icd_mf_specs(mesh):
    """W rows (contexts) over dp; H rows (items) over model; observation
    arrays over dp. The k×k Grams replicate — Lemma 2's k² all-reduce."""
    dp = dp_axes(mesh)
    from repro.core.models.mf import MFParams

    params = MFParams(w=P(dp, None), h=P("model", None))
    data = dict(
        ctx=P(dp), item=P(dp), y=P(dp), alpha=P(dp),
        t_ctx=P(dp), t_item=P(dp), t_perm=P(dp),
    )
    return params, data
