"""Mixed-precision (ZeRO-1 building block) + sharding hints no-op behavior."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models.hints import constrain, sharding_hints
from repro.optim import adamw, apply_updates
from repro.optim.mixed import mixed_precision


def test_mixed_precision_tracks_fp32_trajectory():
    """bf16 live params + fp32 master must follow the pure-fp32 AdamW
    trajectory to bf16 resolution."""
    target = jnp.asarray([0.33, -1.7, 2.4, 0.01])

    def loss(p):
        return jnp.sum((p.astype(jnp.float32) - target) ** 2)

    opt32 = adamw(0.05)
    p32 = jnp.zeros(4, jnp.float32)
    s32 = opt32.init(p32)

    optmx = mixed_precision(adamw(0.05))
    pmx = jnp.zeros(4, jnp.bfloat16)
    smx = optmx.init(pmx)

    for _ in range(150):
        g32 = jax.grad(loss)(p32)
        u, s32 = opt32.update(g32, s32, p32)
        p32 = apply_updates(p32, u)

        gmx = jax.grad(loss)(pmx).astype(jnp.float32)
        u, smx = optmx.update(gmx, smx, pmx)
        pmx = apply_updates(pmx, u)

    # master should match fp32 closely; live bf16 within bf16 eps
    np.testing.assert_allclose(np.asarray(smx["master"]), np.asarray(p32),
                               atol=2e-2)
    np.testing.assert_allclose(np.asarray(pmx, dtype=np.float32),
                               np.asarray(p32), atol=5e-2)


def test_hints_noop_without_context():
    x = jnp.ones((4, 4))
    np.testing.assert_array_equal(constrain(x, ("a", None)), x)


def test_hints_apply_inside_mesh():
    mesh = jax.make_mesh((1,), ("model",))

    def f(x):
        return constrain(x, ("expert", None)) * 2

    with mesh, sharding_hints(expert="model"):
        out = jax.jit(f)(jnp.ones((4, 4)))
    np.testing.assert_array_equal(np.asarray(out), 2 * np.ones((4, 4)))


def test_hints_restore_previous_mapping():
    from repro.models.hints import _current

    with sharding_hints(a="model"):
        with sharding_hints(b="data"):
            assert _current() == {"b": "data"}
        assert _current() == {"a": "model"}  # outer mapping restored
    assert _current() is None
