"""k-separable model catalogue (paper §5) with exact iCD sweeps.

Every module exposes the same low-level surface:

- ``init(key, ...) -> params``            parameter pytree
- ``phi(params, ...) / psi(params, ...)`` the k-separable decomposition
- ``export_psi(params, ...) -> (I, D)``   ψ table for the retrieval engine
- ``build_phi(params, <query>) -> (B, D)`` φ rows for a query batch (the
  serve/eval contract — column conventions in ``serve/engine.py``)
- ``predict(params, ...)``                scores for (context, item) pairs
- ``epoch(params, data, hp, [schedule, sweep_index]) -> params`` one iCD
  epoch (ctx + item sweep); an optional
  :class:`~repro.core.sweeps.SweepSchedule` restricts it to a static
  subspace block plan (rotating / randomized / repeated k_b-blocks)
- ``objective(params, data, hp)``         Lemma-1 objective for monitoring

MF (eq. 15), MF with side information (eq. 20), FM ((k+2)-separable, eq. 26),
PARAFAC (eq. 34, sparse & dense context), Tucker (k₃-separable, eq. 40).

The UNIFIED surface over these modules is the ``Model`` protocol in
:mod:`repro.core.models.api`: ``build_model(name, hp=..., dataset=Dataset(
...))`` returns an adapter with data keyword-only methods (``fit``,
``epoch``, ``export_psi``, ``build_phi``) plus the continual-learning
entry points ``fold_in_user`` / ``fold_in_item`` (closed-form single-row
CD against the frozen other side — ``core/foldin.py``). The serving
engine (``RetrievalEngine.from_model``), ranking eval
(``model_eval_callback`` / ``foldin_ranking_eval``), and the zoo helpers
all construct through it, so no consumer branches on per-model
signatures. The module-level functions here remain the public low-level
API — the adapters delegate, they do not reimplement.
"""

from repro.core.models import api, fm, mf, mfsi, parafac, tucker  # noqa: F401
from repro.core.models.api import Dataset, Model, build_model  # noqa: F401
