"""DCN-v2 (Wang et al., arXiv:2008.13535), stacked cross → deep.

x0 = [dense ‖ 26×16 embeddings] (B, 429);
cross layer: x_{l+1} = x0 ⊙ (W_l x_l + b_l) + x_l (full-rank W, the paper's
strongest variant); deep MLP on top → logit.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import RecsysConfig
from repro.models.common import dense_init, mlp_apply, mlp_init
from repro.models.recsys_common import binary_ce, init_tables, lookup, table_offsets


def _x0_dim(cfg: RecsysConfig) -> int:
    return cfg.n_dense + cfg.n_sparse * cfg.embed_dim


def init_params(key, cfg: RecsysConfig) -> Dict:
    k1, k2, k3 = jax.random.split(key, 3)
    table = init_tables(k1, cfg.table_vocabs, cfg.embed_dim)
    d0 = _x0_dim(cfg)
    cross_keys = jax.random.split(k2, cfg.n_cross_layers)
    return {
        "table": table,
        "cross": [
            {"w": dense_init(k, (d0, d0)), "b": jnp.zeros((d0,))}
            for k in cross_keys
        ],
        "deep": mlp_init(k3, (d0,) + cfg.mlp + (1,)),
    }


def forward(cfg: RecsysConfig, params, dense: jax.Array, sparse_ids: jax.Array):
    emb = lookup(params["table"], table_offsets(cfg.table_vocabs), sparse_ids)
    x0 = jnp.concatenate([dense, emb.reshape(emb.shape[0], -1)], axis=1)
    x = x0
    for layer in params["cross"]:
        x = x0 * (x @ layer["w"] + layer["b"]) + x
    return mlp_apply(params["deep"], x)[:, 0]


def loss_fn(cfg: RecsysConfig, params, batch) -> jax.Array:
    logits = forward(cfg, params, batch["dense"], batch["sparse"])
    return binary_ce(logits, batch["label"])


def score_candidates(cfg: RecsysConfig, params, dense, user_sparse, cand_ids):
    """Retrieval: broadcast the 1-row user features over N candidate ids
    (candidate feature = table 0) and run the cross+deep stack batched."""
    n = cand_ids.shape[0]
    emb = lookup(params["table"], table_offsets(cfg.table_vocabs), user_sparse)
    cand_emb = jnp.take(params["table"], cand_ids + table_offsets(cfg.table_vocabs)[0], axis=0)
    emb_n = jnp.concatenate(
        [cand_emb[:, None, :], jnp.broadcast_to(emb[:, 1:], (n, cfg.n_sparse - 1, cfg.embed_dim))],
        axis=1,
    )
    x0 = jnp.concatenate(
        [jnp.broadcast_to(dense, (n, cfg.n_dense)), emb_n.reshape(n, -1)], axis=1
    )
    x = x0
    for layer in params["cross"]:
        x = x0 * (x @ layer["w"] + layer["b"]) + x
    return mlp_apply(params["deep"], x)[:, 0]
