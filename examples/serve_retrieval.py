"""Serving: batched retrieval against an iCD-MF model through the fused
retrieval engine (paper-native k-separable path, §5.1) — the Pallas
score+top-k kernel streams ψ-table blocks through VMEM with a running
top-K merge, so the (B, n_items) score matrix is never materialized —
plus the chunked jnp reducer that is its reference oracle, and a
streaming leave-one-out ranking eval over the full catalogue.

    PYTHONPATH=src python examples/serve_retrieval.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.models import mf
from repro.eval.ranking import ranking_eval
from repro.serve.engine import RetrievalEngine
from repro.serve.recsys_serve import mf_retrieval_score_fn, retrieval_topk


def main():
    n_users, n_items, k = 1000, 50_000, 64
    params = mf.init(jax.random.PRNGKey(0), n_users, n_items, k)
    engine = RetrievalEngine(
        mf.export_psi(params), lambda ctx: mf.build_phi(params, ctx), k=100
    )

    # batched online requests through the fused kernel
    for batch in (8, 64):
        ctx = jnp.arange(batch)
        jax.block_until_ready(engine.topk(ctx))  # warmup (trace+compile)
        t0 = time.perf_counter()
        scores, ids = engine.topk(ctx)
        jax.block_until_ready(ids)
        dt = time.perf_counter() - t0
        print(f"batch={batch:3d}: {dt * 1e3:7.2f} ms "
              f"({batch * n_items / dt / 1e6:.1f} M cand/s)")

    # engine vs the dense (B, n_items) matmul + lax.top_k path
    dense = jax.lax.top_k(params.w[:8] @ params.h.T, 100)[1]
    assert bool((engine.topk(jnp.arange(8))[1] == dense).all())
    print("engine top-k == dense top-k ✓")

    # chunked jnp reducer (the kernel's reference oracle), batched query
    score = mf_retrieval_score_fn(params.w[:4], params.h)
    scores, ids = retrieval_topk(score, n_items, k=100, chunk=8192)
    full = np.asarray(params.w[:4] @ params.h.T)
    for r in range(4):
        assert set(np.asarray(ids)[r].tolist()) == set(np.argsort(-full[r])[:100].tolist())
    print("chunked top-k == exact top-k ✓")

    # streaming leave-one-out eval: full catalogue, no (n_eval, n_items)
    # score matrix — ψ blocks stream through the kernel per 256-row batch
    rng = np.random.default_rng(0)
    n_eval = 512
    true_items = rng.integers(0, n_items, size=n_eval)
    res = ranking_eval(
        mf.build_phi(params, jnp.arange(n_eval)), mf.export_psi(params),
        true_items, k=100, batch_rows=256,
        exclude=[rng.choice(n_items, size=20, replace=False) for _ in range(n_eval)],
    )
    print(f"streaming eval: recall@100={res['recall@100']:.4f} "
          f"ndcg@100={res['ndcg@100']:.4f} over {res['n_eval']} contexts")


if __name__ == "__main__":
    main()
