"""Architecture config registry.

``get_config(arch_id)`` returns the exact published configuration;
``get_smoke_config(arch_id)`` returns a reduced same-family config for CPU
smoke tests. ``ARCH_IDS`` lists the paper's own iCD configs — the
seed-template LM/GNN/RecSys zoo configs were removed in PR 4 (they were
unrelated to this paper; the shared dataclasses in ``configs.base`` stay
for the generic launch/model code).
"""
from __future__ import annotations

import importlib

ARCH_IDS = [
    # the paper's own models
    "icd-mf",
    "icd-fm",
]

_MODULES = {
    "icd-mf": "repro.configs.icd_mf",
    "icd-fm": "repro.configs.icd_fm",
}


def _module(arch_id: str):
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[arch_id])


def get_config(arch_id: str):
    return _module(arch_id).CONFIG


def get_smoke_config(arch_id: str):
    return _module(arch_id).SMOKE_CONFIG


def get_shapes(arch_id: str):
    """dict shape_name -> ShapeSpec for this arch."""
    return _module(arch_id).SHAPES
