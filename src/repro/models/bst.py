"""BST — Behavior Sequence Transformer (Chen et al., arXiv:1905.06874).

The target item is appended to the behaviour sequence; one post-LN
transformer block (8 heads) contextualizes it; flattened sequence output +
other features feed the 1024-512-256 MLP. embed_dim=32, seq_len=20.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import RecsysConfig
from repro.models.common import dense_init, layer_norm, mlp_apply, mlp_init
from repro.models.recsys_common import binary_ce


def init_params(key, cfg: RecsysConfig) -> Dict:
    ks = jax.random.split(key, 10)
    d = cfg.embed_dim
    seq = cfg.seq_len + 1  # history + target slot
    blocks = []
    for i in range(cfg.n_blocks):
        kb = jax.random.split(ks[2 + i], 6)
        blocks.append({
            "wq": dense_init(kb[0], (d, d)),
            "wk": dense_init(kb[1], (d, d)),
            "wv": dense_init(kb[2], (d, d)),
            "wo": dense_init(kb[3], (d, d)),
            "ln1_s": jnp.ones((d,)), "ln1_b": jnp.zeros((d,)),
            "ffn_w1": dense_init(kb[4], (d, 4 * d)),
            "ffn_b1": jnp.zeros((4 * d,)),
            "ffn_w2": dense_init(kb[5], (4 * d, d)),
            "ffn_b2": jnp.zeros((d,)),
            "ln2_s": jnp.ones((d,)), "ln2_b": jnp.zeros((d,)),
        })
    return {
        "items": 0.01 * jax.random.normal(ks[0], (cfg.item_vocab, d)),
        "pos": 0.01 * jax.random.normal(ks[1], (seq, d)),
        "blocks": blocks,
        "mlp": mlp_init(ks[9], (seq * d,) + cfg.mlp + (1,)),
    }


def _block(cfg, p, x, mask):
    b, s, d = x.shape
    h = cfg.n_heads
    hd = d // h
    q = (x @ p["wq"]).reshape(b, s, h, hd)
    k = (x @ p["wk"]).reshape(b, s, h, hd)
    v = (x @ p["wv"]).reshape(b, s, h, hd)
    sc = jnp.einsum("bshd,bthd->bhst", q, k) / jnp.sqrt(jnp.float32(hd))
    sc = jnp.where(mask[:, None, None, :], sc, -1e30)
    a = jax.nn.softmax(sc, axis=-1)
    o = jnp.einsum("bhst,bthd->bshd", a, v).reshape(b, s, d) @ p["wo"]
    x = layer_norm(x + o, p["ln1_s"], p["ln1_b"])  # post-LN (paper)
    f = jax.nn.relu(x @ p["ffn_w1"] + p["ffn_b1"]) @ p["ffn_w2"] + p["ffn_b2"]
    return layer_norm(x + f, p["ln2_s"], p["ln2_b"])


def forward(cfg: RecsysConfig, params, hist_ids, hist_mask, target_ids):
    b = hist_ids.shape[0]
    seq_ids = jnp.concatenate([hist_ids, target_ids[:, None]], axis=1)
    mask = jnp.concatenate(
        [hist_mask > 0, jnp.ones((b, 1), bool)], axis=1
    )
    x = jnp.take(params["items"], seq_ids, axis=0) + params["pos"][None]
    x = x * mask[..., None]
    for p in params["blocks"]:
        x = _block(cfg, p, x, mask)
    flat = (x * mask[..., None]).reshape(b, -1)
    return mlp_apply(params["mlp"], x=flat, act=jax.nn.leaky_relu)[:, 0]


def loss_fn(cfg: RecsysConfig, params, batch) -> jax.Array:
    logits = forward(cfg, params, batch["hist"], batch["mask"], batch["target"])
    return binary_ce(logits, batch["label"])


def score_candidates(cfg: RecsysConfig, params, hist_ids, hist_mask, cand_ids):
    """Retrieval: the target participates in self-attention, so the block
    re-runs per candidate (chunk-batched)."""
    n = cand_ids.shape[0]
    hist_n = jnp.broadcast_to(hist_ids, (n,) + hist_ids.shape[1:])
    mask_n = jnp.broadcast_to(hist_mask, (n,) + hist_mask.shape[1:])
    return forward(cfg, params, hist_n, mask_n, cand_ids)
