"""Jit'd public wrapper for the fused score+top-K retrieval kernel."""
from repro.kernels import kernel_jit
from repro.kernels.topk_score.kernel import topk_score_pallas


@kernel_jit(static_argnames=("k", "block_b", "block_items"))
def topk_score(phi, psi, k, exclude_mask=None, *, block_b=128,
               block_items=None, interpret=None):
    """Fused streaming top-K over the ψ table: ``(scores, ids) (B, k)``.

    ``exclude_mask`` (B, n_items), nonzero ⇒ never recommend; inadmissible
    slots come back as (−inf, −1). See ``kernel.py`` for the tie policy."""
    return topk_score_pallas(
        phi, psi, k, exclude_mask,
        block_b=block_b, block_items=block_items, interpret=interpret,
    )
