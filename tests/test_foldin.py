"""Closed-form fold-in (``core/foldin.py`` + the Model adapters): CD vs the
float64 normal-equations oracle on every zoo model (user AND item side),
the empty-history / l2=0 corners, FM's structurally-fixed extended columns,
and one-CD-sweep equivalence against ``mf._side_sweep`` restricted to one
row (fold-in IS the training sweep's per-row subproblem)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import foldin
from repro.core.models import mf
from repro.core.models.mf import _side_sweep
from repro.core.models.zoo import ZOO, zoo_model
from repro.core.gram import gram


def _history(rng, n, m=7):
    return rng.choice(n, size=min(m, n), replace=False)


@pytest.mark.parametrize("name", ZOO)
def test_fold_in_user_matches_exact_oracle(name):
    model, params, _ = zoo_model(name, np.random.default_rng(3))
    rng = np.random.default_rng(17)
    table = np.asarray(model.export_psi(params))
    ids = _history(rng, table.shape[0])
    y = rng.integers(1, 4, ids.size).astype(np.float32)
    alpha = (1.0 + rng.random(ids.size)).astype(np.float32)
    row = model.fold_in_user(params, ids, y, alpha, n_sweeps=512, tol=1e-9)
    free, init = model._user_free_init()
    hp = model._foldin_hp()
    exact = foldin.fold_in_exact(
        table, ids, y, alpha, alpha0=hp["alpha0"], l2=hp["l2"],
        free=free, init=init,
    )
    np.testing.assert_allclose(row, exact, rtol=2e-4, atol=2e-5)


@pytest.mark.parametrize("name", ZOO)
def test_fold_in_item_matches_exact_oracle(name):
    model, params, _ = zoo_model(name, np.random.default_rng(3))
    rng = np.random.default_rng(23)
    table = np.asarray(model.phi_table(params))
    ids = _history(rng, table.shape[0])
    row = model.fold_in_item(params, ids, n_sweeps=512, tol=1e-9)
    free, init = model._item_free_init()
    hp = model._foldin_hp()
    exact = foldin.fold_in_exact(
        table, ids, None, None, alpha0=hp["alpha0"], l2=hp["l2"],
        free=free, init=init,
    )
    np.testing.assert_allclose(row, exact, rtol=2e-4, atol=2e-5)


def test_fm_fixed_columns_hold():
    """FM extended coordinates: the constant-1 column that pairs with the
    other side's spec column must come out EXACTLY 1 on a folded row."""
    model, params, _ = zoo_model("fm", np.random.default_rng(3))
    k = model.hp.k
    u = model.fold_in_user(params, [0, 4, 9])
    i = model.fold_in_item(params, [1, 2])
    assert u.shape == (k + 2,) and i.shape == (k + 2,)
    assert u[k + 1] == 1.0      # Φe's constant-1 (meets ψ_spec)
    assert i[k] == 1.0          # Ψe's constant-1 (meets φ_spec)
    # the free spec coordinate DID move (it's being solved, not pinned)
    assert u[k] != 0.0 and i[k + 1] != 0.0


def test_empty_history_l2_zero_stays_finite():
    """m=0, λ=0: the normal system is singular; the CD clamp must return
    finite numbers (the all-zero implicit-prior solution), not NaN/inf."""
    rng = np.random.default_rng(0)
    table = rng.normal(size=(11, 5)).astype(np.float32)
    res = foldin.fold_in_row(table, [], alpha0=0.5, l2=0.0)
    assert np.all(np.isfinite(res.row))
    np.testing.assert_allclose(res.row, np.zeros(5), atol=1e-7)
    # and with l2 > 0 the exact oracle agrees on the empty-history solve
    exact = foldin.fold_in_exact(table, [], alpha0=0.5, l2=0.1)
    got = foldin.fold_in_row(table, [], alpha0=0.5, l2=0.1)
    np.testing.assert_allclose(got.row, exact, atol=1e-6)


def test_one_sweep_matches_mf_side_sweep_single_row():
    """fold_in_row with n_sweeps=1 IS ``mf._side_sweep`` on a (1, k) side:
    same residual cache, same Gram contraction, same Newton step."""
    rng = np.random.default_rng(5)
    n_items, k, m = 13, 6, 8
    h = rng.normal(size=(n_items, k)).astype(np.float32)
    ids = rng.choice(n_items, size=m, replace=False)
    y = rng.integers(1, 4, m).astype(np.float32)
    alpha = (1.0 + rng.random(m)).astype(np.float32)
    hp = mf.MFHyperParams(k=k, alpha0=0.4, l2=0.07)

    got = foldin.fold_in_row(
        h, ids, y, alpha, alpha0=hp.alpha0, l2=hp.l2, eta=hp.eta, n_sweeps=1
    )
    h_j = jnp.asarray(h)
    side, _ = _side_sweep(
        jnp.zeros((1, k), jnp.float32), gram(h_j),
        lambda f: h_j[jnp.asarray(ids), f],
        jnp.zeros(m, jnp.int32), jnp.asarray(alpha), jnp.asarray(-y),
        1, hp,
    )
    np.testing.assert_allclose(got.row, np.asarray(side[0]),
                               rtol=2e-5, atol=2e-6)


def test_fold_in_validation():
    table = np.zeros((4, 3), np.float32)
    with pytest.raises(ValueError):
        foldin.fold_in_row(table, [4], alpha0=1.0, l2=0.1)   # id out of range
    with pytest.raises(ValueError):
        foldin.fold_in_row(table, [0], y=np.ones(2), alpha0=1.0, l2=0.1)
    with pytest.raises(ValueError):
        foldin.fold_in_row(table, [0], alpha0=1.0, l2=0.1,
                           free=np.ones(2, bool))            # bad mask shape
