"""Pure-jnp oracle for flash attention (dense softmax attention)."""
import math

import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal=True, window=None, softcap=None,
                  q_offset=0, kv_len=None):
    sq, d = q.shape
    skv = k.shape[0]
    kv_len = skv if kv_len is None else kv_len
    s = (q.astype(jnp.float32) @ k.astype(jnp.float32).T) / math.sqrt(d)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    q_pos = q_offset + jnp.arange(sq)[:, None]
    kv_pos = jnp.arange(skv)[None, :]
    mask = kv_pos < kv_len
    if causal:
        mask = mask & (q_pos >= kv_pos)
    if window is not None:
        mask = mask & (kv_pos > q_pos - window)
    s = jnp.where(mask, s, NEG_INF)
    p = jnp.exp(s - jnp.max(s, axis=1, keepdims=True))
    p = jnp.where(mask, p, 0.0)
    denom = jnp.maximum(jnp.sum(p, axis=1, keepdims=True), 1e-30)
    return ((p / denom) @ v.astype(jnp.float32)).astype(q.dtype)
