"""End-to-end observability: train -> publish -> serve under injected
faults -> export metrics (JSONL + Prometheus text) and a Perfetto trace.

One registry and one tracer (``repro.obs``) thread through every layer:

  * training — ``fit_metrics_callback`` records epoch wall time, the loss
    trajectory, SweepSchedule block visits, and the analytic cd_sweep
    kernel cost, composed with a ``PsiPublisher`` that snapshots ψ into
    the live mesh at each epoch boundary;
  * serving — the ``MicroBatcher`` and ``FaultTolerantRetrievalMesh``
    share the registry (queue depth, flush reasons, cache hits, dispatch/
    failover/retry counters, per-replica latency histograms, kernel HBM/
    FLOP cost counters) and the tracer, so one batched request under an
    injected replica kill exports as a single correlated trace:
    submit -> queue -> flush -> dispatch -> failover -> merge;
  * export — ``results/obs/metrics.jsonl``, ``metrics.prom``, and
    ``trace.json`` (open the last in Perfetto / chrome://tracing).

    PYTHONPATH=src python examples/observability.py
"""
import json
import os
import time

import jax
import numpy as np

from repro.core.models.api import Dataset, build_model
from repro.core.models.mf import MFHyperParams
from repro.core.sweeps import SweepSchedule
from repro.data.synthetic import make_implicit_dataset
from repro.obs import (
    MetricsRegistry,
    Tracer,
    compose_callbacks,
    fit_metrics_callback,
    metrics_jsonl,
    trace_for_ticket,
    write_metrics,
    write_trace,
)
from repro.serve.batcher import MicroBatcher
from repro.serve.mesh import (
    FaultInjector,
    FaultTolerantRetrievalMesh,
    RetryPolicy,
)
from repro.serve.publish import PsiPublisher
from repro.sparse.interactions import build_interactions

OUT_DIR = os.path.join("results", "obs")


def main():
    registry = MetricsRegistry(clock=time.perf_counter)
    tracer = Tracer(clock=time.perf_counter)

    # --- train: metrics callback + live psi publishes --------------------
    n_users, n_items, k, k_b = 200, 120, 16, 4
    ds = make_implicit_dataset(n_users=n_users, n_items=n_items, seed=0)
    ev = ds.events
    data = build_interactions(
        ev[:, 0], ev[:, 1], np.ones(len(ev)), np.full(len(ev), 2.0),
        n_users, n_items, alpha0=0.3,
    )
    hp = MFHyperParams(k=k, alpha0=0.3, l2=0.05)
    model = build_model("mf", hp=hp, dataset=Dataset(data=data))
    params = model.init(jax.random.PRNGKey(0))

    injector = FaultInjector()
    mesh = FaultTolerantRetrievalMesh(
        lambda ctx: model.build_phi(params, ctx),
        n_shards=2, n_replicas=2, k=10, injector=injector,
        retry=RetryPolicy(max_attempts=3, deadline=5e-3),
        registry=registry, tracer=tracer,
    )
    schedule = SweepSchedule(kind="rotating", block=k_b)
    publisher = PsiPublisher(mesh, model.export_psi, every=1,
                             registry=registry)
    d_pad = -(-n_items // 128) * 128
    cb = compose_callbacks(
        fit_metrics_callback(
            registry=registry, objective=model.objective,
            schedule=schedule, n_dims=k, block=k_b,
            cd_shape=(n_users, d_pad, k),
        ),
        publisher,
    )
    params = model.fit(params, n_epochs=4, callback=cb, schedule=schedule)
    metrics_cb = cb.callbacks[0]
    losses = [loss for _, _, loss in metrics_cb.history]
    print(f"train: {len(metrics_cb.history)} epochs, loss "
          f"{losses[0]:.4f} -> {losses[-1]:.4f}; "
          f"psi versions published: {[v for _, v in publisher.versions]}")

    # --- serve under an injected replica kill ----------------------------
    injector.fail(0, 0, "error")     # sticky: replica (0,0) dies; R=2
    batcher = MicroBatcher(
        lambda phi, eids: mesh.topk_phi(phi, exclude_ids=eids),
        max_batch=8, max_delay=5e-3, clock=time.perf_counter,
        version_fn=lambda: mesh.version,
        registry=registry, tracer=tracer,
    )
    phi_all = np.asarray(model.build_phi(params, np.arange(n_users)))
    tickets = [batcher.submit(phi_all[u], key=("user", int(u)))
               for u in range(8)]
    batcher.step()
    batcher.flush()
    res = batcher.result(tickets[0])
    batcher.drain()
    ms = mesh.stats
    print(f"serve: {ms['dispatches']} dispatches, {ms['faults']} fault(s), "
          f"{ms['failovers']} failover(s), "
          f"coverage={res.coverage:.4f} (kill was invisible: R=2)")
    assert ms["faults"] >= 1 and ms["failovers"] >= 1
    assert res.coverage == 1.0

    # one ticket's whole story, correlated across layers
    span_names = {s.name for s in trace_for_ticket(tracer, tickets[0])}
    print(f"trace[ticket {tickets[0]}]: spans {sorted(span_names)}")
    assert {"request", "queue", "flush", "dispatch", "merge"} <= span_names

    # --- export ----------------------------------------------------------
    os.makedirs(OUT_DIR, exist_ok=True)
    jsonl_path = os.path.join(OUT_DIR, "metrics.jsonl")
    prom_path = os.path.join(OUT_DIR, "metrics.prom")
    trace_path = os.path.join(OUT_DIR, "trace.json")
    write_metrics(jsonl_path, registry)
    write_metrics(prom_path, registry)
    write_trace(trace_path, tracer)
    n_lines = len(metrics_jsonl(registry).splitlines())
    with open(trace_path) as fh:
        n_events = len(json.load(fh)["traceEvents"])
    print(f"export: {n_lines} metric series -> {jsonl_path} / {prom_path}; "
          f"{n_events} trace events -> {trace_path} "
          "(open in Perfetto / chrome://tracing)")


if __name__ == "__main__":
    main()
