"""Shared machinery for iCD column sweeps.

The TPU adaptation of Algorithm 1/2/3 (see DESIGN.md §3): for a fixed
embedding dimension ``f*`` the Newton updates of all coordinates on one side
are independent, so each inner loop of the paper becomes ONE vectorized
column update:

    gather → segment-reduce (explicit part from the residual cache)
    k-vector contraction with the opposite Gram (implicit part, Lemma 3)
    fused Newton step  θ ← θ − η·(L'/2 + α₀R'/2 + λθ)/(L''/2 + α₀R''/2 + λ)
    rank-1 residual patch

All helpers are jit-friendly; the f* loop is a ``lax.fori_loop`` with the
parameter matrix as carry.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class NewtonParts(NamedTuple):
    """Halved derivative pieces; the common factor 2 of eqs. (2,3,13,14)
    cancels in the Newton ratio so we carry L'/2 etc. throughout."""

    grad: jax.Array  # L'/2 + α₀·R'/2   (no L2 term yet)
    hess: jax.Array  # L''/2 + α₀·R''/2 (no L2 term yet)


def newton_delta(
    parts: NewtonParts, theta: jax.Array, l2: float, eta: float
) -> jax.Array:
    """η-damped Newton step on the 1-D quadratic (exact at η=1 for
    multilinear models, paper §3.2). Returns Δθ."""
    num = parts.grad + l2 * theta
    den = parts.hess + l2
    return -eta * num / den


def take_col(m: jax.Array, f) -> jax.Array:
    """m[:, f] with a traced index."""
    return jax.lax.dynamic_slice_in_dim(m, f, 1, axis=1)[:, 0]


def put_col(m: jax.Array, f, col: jax.Array) -> jax.Array:
    """m with column f replaced (traced index)."""
    return jax.lax.dynamic_update_slice_in_dim(m, col[:, None], f, axis=1)


def residuals_from_factors(
    phi: jax.Array, psi: jax.Array, ctx: jax.Array, item: jax.Array, y: jax.Array
) -> jax.Array:
    """e = ŷ − ȳ on observed pairs: Σ_f φ_f(c)ψ_f(i) − ȳ, per nnz."""
    scores = jnp.sum(
        jnp.take(phi, ctx, axis=0) * jnp.take(psi, item, axis=0), axis=-1
    )
    return scores - y


def to_item_major(e_ctx_major: jax.Array, t_perm: jax.Array) -> jax.Array:
    """Permute a per-nnz vector from context-major to item-major order."""
    return jnp.take(e_ctx_major, t_perm)


def to_ctx_major(e_item_major: jax.Array, t_perm: jax.Array) -> jax.Array:
    """Inverse permutation of :func:`to_item_major`."""
    return jnp.zeros_like(e_item_major).at[t_perm].set(e_item_major)
