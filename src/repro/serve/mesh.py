"""Fault-tolerant serving mesh: replication, health-checked failover, and
graceful degradation for the sharded retrieval cluster.

``serve/cluster.py`` gives one copy of each ψ row-range: lose a shard and
its slice of the catalogue silently vanishes. This module is the tier that
makes the cluster OPERABLE under the failures a "millions of users" serving
regime implies (Rendle 2021 frames large-catalogue retrieval as exactly
this availability/tail-latency problem):

  replication — :class:`ReplicaSet` places each row range on R replica
    slabs (round-robin across devices so copies of the same shard land on
    DIFFERENT devices), with per-replica health state and two routing
    policies: ``round_robin`` (throughput fan-out) and
    ``least_outstanding`` (tail-latency under skew). Every replica runs
    the identical fused-kernel program (``cluster.shard_topk``) with the
    same ``id_offset``/``n_valid`` meta, so WHICH replica answered is
    unobservable in the results — failover is bit-invisible.

  failure detection — three signals feed the per-replica health state:
    (1) hard failures (a dispatch raises — or the injectable
    :class:`FaultInjector` makes it raise, so every failure path is
    testable without killing real processes); (2) latency: per-replica
    query wall-times stream into a :class:`ShardHealthMonitor`
    (``runtime.health.StragglerWatchdog`` keyed by ``(shard, replica)``) —
    a replica whose median latency exceeds the fleet's by ``threshold``×
    for ``patience`` checks is flagged and routed around; (3) staleness: a
    replica still serving an old table version (stuck canary, failed
    flip) is refused before dispatch.

  failover + re-placement — a failed dispatch fails over to the next live
    replica of the same range (no backoff for failover: another copy is
    already warm). A replica struck out ``fail_threshold`` times is marked
    dead; :meth:`FaultTolerantRetrievalMesh.heal` then re-places the
    orphaned row range onto a surviving device from the publisher's
    authoritative copy — the ``ElasticMeshManager`` recovery shape
    (rebuild placement over the surviving device set), applied per shard.

  bounded, deadline-aware retries — :class:`RetryPolicy` gives each
    request a budget: at most ``max_attempts`` dispatches per shard,
    exponential backoff between SAME-SET retries, and every sleep capped
    by the request's remaining ``deadline`` budget — a retry can never
    blow the micro-batcher's ``max_delay`` contract (wire
    ``retry.deadline = batcher.max_delay``). Injected fault latencies
    count against the budget exactly like real ones.

  graceful degradation — a row range with NO live replica does not hang or
    raise: the query completes over the surviving shards and the
    :class:`~repro.serve.cluster.TopKResult` reports ``coverage < 1.0``
    plus the dead global-id ranges. The same contract flows through the
    batcher's ticket results and ``eval/ranking.py``'s sharded path.

  staged rollout — ``publish.StagedRollout`` drives the canary protocol
    (:meth:`begin_canary` → :meth:`mirror_check` → :meth:`promote_canary`
    / :meth:`rollback_canary`): the next ψ table is installed on ONE
    canary replica per shard, health-checked under mirrored traffic
    against the live table, and only then flipped everywhere — a bad
    table rolls back without downtime and without ever serving a user.

Everything is single-process and clock-injectable (like ``MicroBatcher``):
tests drive simulated clocks and the :class:`FaultInjector` instead of
killing processes, so the chaos suite is deterministic.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs.costs import KernelCostRecorder
from repro.obs.metrics import StatsView, next_instance_id, resolve_registry
from repro.runtime.health import StragglerWatchdog
from repro.serve.cluster import (
    PsiShardSet,
    TopKResult,
    colocate_parts,
    coverage_fraction,
    dead_item_ranges,
    empty_topk,
    resolve_cluster_block_items,
    shard_psi,
    shard_topk,
)
from repro.kernels.topk_score.ops import topk_merge_shards


# ------------------------------------------------------------------ failures
class ReplicaFailure(RuntimeError):
    """A single replica failed one dispatch (crash, injected error)."""

    def __init__(self, msg: str = "replica failure", latency: float = 0.0):
        super().__init__(msg)
        self.latency = float(latency)


class ReplicaTimeout(ReplicaFailure):
    """A dispatch exceeded its time allowance; ``latency`` is what it
    burned from the request's deadline budget before being abandoned."""


class StaleReplicaError(ReplicaFailure):
    """The replica's installed table version lags the live version — it
    must not answer (a stale ψ would silently serve old scores)."""


class FaultInjector:
    """Injectable failure source — the chaos-testing hook.

    ``fail(shard, replica, mode)`` arms a fault on one replica:

      * ``"error"``   — its next dispatches raise :class:`ReplicaFailure`;
      * ``"timeout"`` — raise :class:`ReplicaTimeout` carrying ``latency``
        seconds of burned deadline budget;
      * ``"stale"``   — raise :class:`StaleReplicaError` (simulates a
        replica stuck on an old table version).

    Faults are sticky until :meth:`heal`; ``count=n`` makes a fault
    transient (auto-disarms after n dispatches — the retry-path test)."""

    def __init__(self):
        self._faults: Dict[Tuple[int, int], dict] = {}
        self.triggered = 0

    def fail(self, shard: int, replica: int, mode: str = "error", *,
             latency: float = 0.0, count: Optional[int] = None) -> None:
        if mode not in ("error", "timeout", "stale"):
            raise ValueError(f"unknown fault mode {mode!r}")
        self._faults[(shard, replica)] = {
            "mode": mode, "latency": float(latency), "count": count,
        }

    def heal(self, shard: Optional[int] = None,
             replica: Optional[int] = None) -> None:
        """Disarm faults: all of them, one shard's, or one replica's."""
        if shard is None:
            self._faults.clear()
            return
        for key in list(self._faults):
            if key[0] == shard and (replica is None or key[1] == replica):
                del self._faults[key]

    def before_dispatch(self, shard: int, replica: int) -> None:
        f = self._faults.get((shard, replica))
        if f is None:
            return
        if f["count"] is not None:
            f["count"] -= 1
            if f["count"] < 0:
                del self._faults[(shard, replica)]
                return
        self.triggered += 1
        if f["mode"] == "timeout":
            raise ReplicaTimeout(
                f"injected timeout on replica ({shard}, {replica})",
                latency=f["latency"],
            )
        if f["mode"] == "stale":
            raise StaleReplicaError(
                f"injected stale table on replica ({shard}, {replica})"
            )
        raise ReplicaFailure(
            f"injected error on replica ({shard}, {replica})",
            latency=f["latency"],
        )


# ------------------------------------------------------------------- policy
@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded retries with deadline-aware exponential backoff.

    ``max_attempts`` caps dispatches per shard per request. ``backoff_base``
    seconds doubles per retry (attempt i sleeps ``base · 2^(i-1)``), but a
    sleep is only taken when it FITS the remaining ``deadline`` budget —
    otherwise the shard gives up immediately (degrade beats blowing the
    caller's latency contract). ``deadline=None`` means unbudgeted (retries
    still bounded by ``max_attempts``). Set ``deadline`` to the
    micro-batcher's ``max_delay`` so queue wait + retries share one bound.
    """

    max_attempts: int = 3
    backoff_base: float = 1e-4
    deadline: Optional[float] = None

    def backoff(self, attempt: int) -> float:
        """Sleep before retry number ``attempt`` (1-based)."""
        return self.backoff_base * (2.0 ** max(0, attempt - 1))


# ------------------------------------------------------------------ replicas
@dataclasses.dataclass
class Replica:
    """One placed copy of one ψ row-range, with live health state."""

    shard: int
    idx: int                      # replica slot within the shard
    slab: jax.Array               # (rows_per, D)
    device: Optional[object]
    version: int
    alive: bool = True
    canary: bool = False          # staged next-version copy; not routed
    outstanding: int = 0          # in-flight dispatches (least_outstanding)
    served: int = 0
    failures: int = 0             # consecutive failures (reset on success)
    dead_reason: Optional[str] = None

    @property
    def key(self) -> Tuple[int, int]:
        return (self.shard, self.idx)


class ReplicaSet:
    """R health-tracked replicas of every shard of one table snapshot.

    Placement: replica r of shard s goes on ``devices[(s + r) % D]`` — the
    rotation guarantees (whenever R ≤ D) that copies of the SAME row range
    live on DIFFERENT devices, so one device loss never kills a range.

    Routing (:meth:`pick`): ``round_robin`` cycles the live replicas of a
    shard (throughput); ``least_outstanding`` picks the live replica with
    the fewest in-flight dispatches (tail latency). Dead replicas are
    never picked; a shard with zero live replicas has no route and the
    query layer degrades.
    """

    def __init__(
        self,
        table: PsiShardSet,
        n_replicas: int = 2,
        *,
        devices: Optional[Sequence] = None,
        policy: str = "round_robin",
    ):
        if n_replicas < 1:
            raise ValueError(f"n_replicas must be >= 1, got {n_replicas}")
        if policy not in ("round_robin", "least_outstanding"):
            raise ValueError(f"unknown routing policy {policy!r}")
        self.table = table              # authoritative source copy
        self.n_replicas = int(n_replicas)
        self.devices = list(devices) if devices is not None else None
        self.policy = policy
        self._rr = [0] * table.n_shards
        self.replicas: List[List[Replica]] = [
            [self._place(s, r) for r in range(self.n_replicas)]
            for s in range(table.n_shards)
        ]

    # ----------------------------------------------------------- placement
    def _device_for(self, s: int, r: int):
        if not self.devices:
            return None
        return self.devices[(s + r) % len(self.devices)]

    def _place(self, s: int, r: int, device=None) -> Replica:
        dev = device if device is not None else self._device_for(s, r)
        slab = self.table.shards[s]
        if dev is not None:
            slab = jax.device_put(slab, dev)
        return Replica(shard=s, idx=r, slab=slab, device=dev,
                       version=self.table.version)

    # ------------------------------------------------------------- health
    @property
    def n_shards(self) -> int:
        return self.table.n_shards

    @property
    def version(self) -> int:
        return self.table.version

    def live(self, s: int) -> List[Replica]:
        return [r for r in self.replicas[s] if r.alive and not r.canary]

    def dead_shards(self) -> List[int]:
        return [s for s in range(self.n_shards) if not self.live(s)]

    def mark_dead(self, s: int, idx: int, reason: str = "failed") -> None:
        for rep in self.replicas[s]:
            if rep.idx == idx and rep.alive:
                rep.alive = False
                rep.dead_reason = reason

    def mark_live(self, s: int, idx: int) -> None:
        for rep in self.replicas[s]:
            if rep.idx == idx:
                rep.alive = True
                rep.failures = 0
                rep.dead_reason = None

    # ------------------------------------------------------------- routing
    def pick(self, s: int) -> Replica:
        live = self.live(s)
        if not live:
            raise ReplicaFailure(f"shard {s} has no live replica")
        if self.policy == "least_outstanding":
            return min(live, key=lambda r: (r.outstanding, r.idx))
        rep = live[self._rr[s] % len(live)]
        self._rr[s] += 1
        return rep

    # ----------------------------------------------------- re-placement
    def replace(self, s: int, *, device=None) -> Replica:
        """Re-place shard ``s``'s orphaned row range as a fresh replica
        built from the authoritative table copy, on a SURVIVING device —
        the per-shard mirror of ``ElasticMeshManager.on_failure`` (rebuild
        placement over the device set minus the casualties). The new
        replica takes the lowest free slot index."""
        if device is None and self.devices:
            tainted = {id(r.device) for r in self.replicas[s]
                       if not r.alive and r.device is not None}
            candidates = [d for d in self.devices if id(d) not in tainted]
            if not candidates:       # every device saw a death: any port
                candidates = list(self.devices)
            loads: Dict[int, int] = {}
            for row in self.replicas:
                for rep in row:
                    if rep.alive and rep.device is not None:
                        loads[id(rep.device)] = loads.get(id(rep.device), 0) + 1
            device = min(candidates, key=lambda d: loads.get(id(d), 0))
        used = {r.idx for r in self.replicas[s]}
        idx = next(i for i in itertools.count() if i not in used)
        rep = self._place(s, idx, device=device)
        self.replicas[s].append(rep)
        return rep


# ------------------------------------------------------------------- health
class ShardHealthMonitor:
    """Per-replica query-latency watchdog for the serving mesh.

    Wraps :class:`repro.runtime.health.StragglerWatchdog` with
    ``(shard, replica)`` keys and query wall-times as the reported step
    times: a replica whose median latency exceeds the fleet median by
    ``threshold``× for ``patience`` consecutive checks comes back from
    :meth:`flagged` — the mesh then routes around it exactly like a hard
    failure (health-checked failover). Quiet (dead) replicas drop out of
    the baseline automatically (the watchdog's staleness horizon)."""

    def __init__(self, threshold: float = 3.0, patience: int = 3,
                 window: int = 16):
        self._wd = StragglerWatchdog(
            threshold=threshold, patience=patience, window=window
        )

    def observe(self, key: Tuple[int, int], latency: float) -> None:
        self._wd.report(key, latency)

    def flagged(self) -> List[Tuple[int, int]]:
        return list(self._wd.check())


# --------------------------------------------------------------------- mesh
class FaultTolerantRetrievalMesh:
    """Replicated, health-checked, degradation-aware retrieval service.

    The drop-in hardened superset of
    :class:`~repro.serve.cluster.ShardedRetrievalCluster`::

        mesh = FaultTolerantRetrievalMesh(
            lambda ctx: mf.build_phi(params, ctx),
            n_shards=4, n_replicas=2, k=100,
            retry=RetryPolicy(max_attempts=3, deadline=batcher.max_delay))
        mesh.publish(mf.export_psi(params))
        res = mesh.topk(user_ids)          # TopKResult
        res.coverage, res.dead_ranges      # the degradation contract

    Query semantics: bit-identical to the unreplicated cluster (and the
    single-device engine) whenever every row range has ≥ 1 live replica —
    replicas are exact copies running the same program, so a mid-stream
    replica kill under R ≥ 2 is invisible in the results. When a range has
    NO live replica the query completes over the survivors with
    ``coverage < 1.0`` and the dead ranges reported.

    ``publish`` snapshots are versioned double-buffered ReplicaSets (same
    flip protocol as the cluster); the canary methods implement the staged
    rollout (see module docstring and ``publish.StagedRollout``).
    """

    def __init__(
        self,
        phi_fn: Optional[Callable[..., jax.Array]] = None,
        *,
        n_shards: int = 2,
        n_replicas: int = 2,
        k: int = 100,
        block_items: Optional[int] = None,
        devices: Optional[Sequence] = None,
        policy: str = "round_robin",
        retry: Optional[RetryPolicy] = None,
        injector: Optional[FaultInjector] = None,
        monitor: Optional[ShardHealthMonitor] = None,
        fail_threshold: int = 1,
        auto_heal: bool = False,
        clock: Callable[[], float] = time.monotonic,
        sleep: Optional[Callable[[float], None]] = None,
        psi_table: Optional[jax.Array] = None,
        retrieval: str = "exact",
        ann=None,                                  # serve.ann.AnnConfig
        registry=None,
        tracer=None,
    ):
        from repro.serve.publish import VersionedTable

        if retrieval not in ("exact", "ivf"):
            raise ValueError(f"retrieval must be 'exact' or 'ivf', got {retrieval!r}")
        self.retrieval = retrieval
        self.ann = ann
        self._ivf: Dict[int, tuple] = {}   # table version → per-shard indexes
        self.phi_fn = phi_fn
        self.n_shards = int(n_shards)
        self.n_replicas = int(n_replicas)
        self.k = int(k)
        self.block_items = block_items
        self.devices = devices
        self.policy = policy
        self.retry = retry or RetryPolicy()
        self.injector = injector
        self.monitor = monitor or ShardHealthMonitor()
        self.fail_threshold = int(fail_threshold)
        self.auto_heal = bool(auto_heal)
        self.clock = clock
        self.sleep = sleep if sleep is not None else (lambda dt: None)
        self._set = VersionedTable()
        self._canary: Optional[PsiShardSet] = None
        # counters live on the metrics registry (obs/metrics.py) with a
        # per-instance label; ``self.stats`` is the live back-compat view.
        # ``tracer`` opts into dispatch/retry/failover spans that nest
        # under the batcher's flush span (one trace per request).
        self.registry = resolve_registry(registry)
        self.tracer = tracer
        reg, inst = self.registry, next_instance_id()
        self._inst = inst
        lab = ("instance",)

        def _c(name, help_text):
            return reg.counter(name, help_text, labels=lab).labels(
                instance=inst)

        counter_specs = {
            "queries": ("serve_mesh_queries_total", "topk_phi requests"),
            "dispatches": ("serve_mesh_dispatches_total",
                           "per-replica dispatch attempts"),
            "failovers": ("serve_mesh_failovers_total",
                          "failovers to another live replica"),
            "retries": ("serve_mesh_retries_total",
                        "same-set retries (after backoff)"),
            "faults": ("serve_mesh_faults_total",
                       "dispatches that raised (real or injected)"),
            "replicas_died": ("serve_mesh_replicas_died_total",
                              "replicas marked dead"),
            "replicas_replaced": ("serve_mesh_replicas_replaced_total",
                                  "replicas re-placed by heal()"),
            "degraded_queries": ("serve_mesh_degraded_queries_total",
                                 "queries answered with coverage < 1"),
            "backoff_slept_s": ("serve_mesh_backoff_slept_seconds_total",
                                "total backoff sleep"),
            "deadline_gaveups": ("serve_mesh_deadline_gaveups_total",
                                 "shards given up on over the deadline "
                                 "budget"),
            "fault_burned_s": ("serve_mesh_fault_burned_seconds_total",
                               "deadline budget burned by failed "
                               "dispatches (real wall time + injected "
                               "fault latency)"),
            "heals": ("serve_mesh_heals_total", "heal() invocations"),
            "canary_staged": ("serve_mesh_canary_staged_total",
                              "canary tables staged"),
            "canary_promoted": ("serve_mesh_canary_promoted_total",
                                "canaries promoted live"),
            "canary_rolled_back": ("serve_mesh_canary_rolled_back_total",
                                   "canaries rolled back"),
        }
        self._m = {key: _c(name, help_text)
                   for key, (name, help_text) in counter_specs.items()}
        _float_keys = ("backoff_slept_s", "fault_burned_s")
        self.stats = StatsView({
            key: (lambda ch=ch: ch.value) if key in _float_keys
            else (lambda ch=ch: int(ch.value))
            for key, ch in self._m.items()
        })
        self._m_version = reg.gauge(
            "serve_mesh_version", "live table version", labels=lab,
        ).labels(instance=inst)
        self._m_coverage = reg.gauge(
            "serve_mesh_coverage", "coverage of the last query", labels=lab,
        ).labels(instance=inst)
        self._lat_fam = reg.histogram(
            "serve_mesh_replica_latency_seconds",
            "per-(shard,replica) dispatch wall time (the health monitor's "
            "own observations)", labels=("instance", "shard", "replica"))
        self._lat_children: Dict[Tuple[int, int], object] = {}
        self._costs = KernelCostRecorder(reg)
        if psi_table is not None:
            self.publish(psi_table)

    # ------------------------------------------------------------- publish
    def publish(self, psi_table: jax.Array) -> int:
        """Shard, replicate, version, and atomically flip a ψ snapshot
        live (the unstaged path — see :meth:`begin_canary` for the staged
        rollout). Returns the new version."""
        version = self._set.publish(
            lambda version: ReplicaSet(
                shard_psi(psi_table, self.n_shards, version=version),
                self.n_replicas, devices=self.devices, policy=self.policy,
            )
        )
        self._m_version.set(version)
        return version

    def publish_delta(self, rows, ids) -> int:
        """Incremental publish for fold-in rows: patch/append ψ ``rows`` at
        global item ``ids`` onto the authoritative table copy and flip the
        rebuilt ReplicaSet live under a normal version bump. Every replica
        is rebuilt at the new version, so the stale-refusal guard
        (:class:`StaleReplicaError` before dispatch) keeps holding; a
        staged canary (if any) must be resolved first — its row geometry
        may no longer match after an append. Returns the new version."""
        from repro.serve.publish import apply_delta, dense_table

        if self._canary is not None:
            raise RuntimeError(
                "cannot delta-publish with a canary staged — promote or "
                "roll it back first"
            )
        old_table = self.table
        old_indexes = self._ivf.get(old_table.version)
        base = dense_table(old_table)
        version = self.publish(jnp.asarray(apply_delta(base, rows, ids)))
        if self.retrieval == "ivf" and old_indexes is not None:
            # fold the delta into the live indexes (nearest-cluster append,
            # staleness-counted; see serve/ann.py) instead of re-running
            # k-means per delta — unless the shard geometry changed
            from repro.serve.ann import fold_delta_indexes

            new_table = self.table
            if (new_table.rows_per == old_table.rows_per
                    and new_table.n_shards == old_table.n_shards):
                self._ivf = {version: fold_delta_indexes(
                    old_indexes, new_table, rows, ids, self._ann_cfg(),
                    registry=self.registry,
                )}
        return version

    def _ann_cfg(self):
        from repro.serve.ann import AnnConfig

        return self.ann or AnnConfig()

    def _ivf_indexes(self, table: PsiShardSet) -> tuple:
        """Per-shard IVF indexes for one snapshot, lazily built and keyed
        on the publish version. Shared by every replica of a shard — the
        index is a function of the shard's CONTENT, which replicas mirror
        bit-exactly, so failover never changes the index either."""
        cached = self._ivf.get(table.version)
        if cached is None:
            from repro.serve.ann import build_shard_indexes

            cached = build_shard_indexes(table, self._ann_cfg())
            self._ivf = {table.version: cached}
        return cached

    @property
    def replica_set(self) -> ReplicaSet:
        return self._set.active

    @property
    def table(self) -> PsiShardSet:
        return self.replica_set.table

    @property
    def version(self) -> int:
        return self._set.version

    @property
    def n_items(self) -> int:
        return self.table.n_items

    # -------------------------------------------------------------- health
    def apply_health_check(self) -> List[Tuple[int, int]]:
        """Route around latency stragglers: every replica the monitor
        flags is marked dead (reason ``"slow"``). Returns the casualties.
        Call from the serving loop's cadence (or rely on per-query hard
        failures — both paths end in the same routing state)."""
        reaped = []
        rs = self._set.active
        for (s, idx) in self.monitor.flagged():
            live = {r.idx for r in rs.live(s)}
            if idx in live:
                rs.mark_dead(s, idx, reason="slow")
                self._m["replicas_died"].inc()
                reaped.append((s, idx))
        if reaped and self.auto_heal:
            self.heal()
        return reaped

    def heal(self) -> List[Tuple[int, int]]:
        """Re-place orphaned capacity: every shard below its replication
        target gets fresh replicas rebuilt from the authoritative table
        copy on surviving devices. Returns the new (shard, idx) pairs."""
        rs = self._set.active
        self._m["heals"].inc()
        placed = []
        for s in range(rs.n_shards):
            while len(rs.live(s)) < self.n_replicas:
                rep = rs.replace(s)
                self._m["replicas_replaced"].inc()
                placed.append(rep.key)
        return placed

    def _replica_latency(self, s: int, idx: int):
        ch = self._lat_children.get((s, idx))
        if ch is None:
            ch = self._lat_fam.labels(
                instance=self._inst, shard=str(s), replica=str(idx))
            self._lat_children[(s, idx)] = ch
        return ch

    # --------------------------------------------------------------- query
    def phi(self, *query) -> jax.Array:
        return jnp.asarray(self.phi_fn(*query), jnp.float32)

    def topk(self, *query, k: Optional[int] = None,
             exclude_mask: Optional[jax.Array] = None,
             exclude_ids: Optional[jax.Array] = None,
             budget: Optional[float] = None) -> TopKResult:
        return self.topk_phi(
            self.phi(*query), k=k, exclude_mask=exclude_mask,
            exclude_ids=exclude_ids, budget=budget,
        )

    def topk_phi(
        self,
        phi_rows: jax.Array,
        *,
        k: Optional[int] = None,
        exclude_mask: Optional[jax.Array] = None,
        exclude_ids: Optional[jax.Array] = None,
        budget: Optional[float] = None,
    ) -> TopKResult:
        """(B, k) :class:`TopKResult` with the degradation contract.

        ``budget`` (seconds) overrides ``retry.deadline`` as this request's
        retry allowance — the batcher path sets it so queue wait plus
        retries stay inside ``max_delay``. The whole request is served
        from ONE ReplicaSet snapshot (version-consistent)."""
        rs = self._set.active  # one snapshot end-to-end
        table = rs.table
        k = k or self.k
        phi_rows = jnp.asarray(phi_rows, jnp.float32)
        b = int(phi_rows.shape[0])
        indexes = None
        block_items = self.block_items
        if self.retrieval == "ivf":
            if exclude_mask is not None:
                raise ValueError(
                    "retrieval='ivf' takes exclude_ids (global id lists), "
                    "not a dense exclude_mask"
                )
            # IVF dispatch resolves its own per-block tiling; the replica
            # failover/retry/health machinery below is retrieval-agnostic
            indexes = self._ivf_indexes(table)
        elif block_items is None:
            excl_l = 0 if exclude_ids is None else int(exclude_ids.shape[1])
            block_items = resolve_cluster_block_items(
                table, b, k, excl_l=excl_l
            )
        self._m["queries"].inc()
        budget = self.retry.deadline if budget is None else budget
        parts_s, parts_i, dead = [], [], []
        for s in range(table.n_shards):
            out = self._query_shard(
                rs, s, phi_rows, k, exclude_mask, exclude_ids,
                block_items, budget, indexes=indexes,
            )
            if out is None:
                dead.append(s)
            else:
                parts_s.append(out[0])
                parts_i.append(out[1])
        if dead:
            self._m["degraded_queries"].inc()
        coverage = coverage_fraction(table, dead)
        ranges = dead_item_ranges(table, dead)
        self._m_coverage.set(coverage)
        if not parts_s:
            es, ei = empty_topk(b, k)
            return TopKResult(es, ei, coverage, ranges)
        if len(parts_s) == 1:
            return TopKResult(parts_s[0], parts_i[0], coverage, ranges)
        merge_span = None
        if self.tracer is not None:
            merge_span = self.tracer.begin(
                "merge", shards=len(parts_s), k=k)
        ms, mi = topk_merge_shards(
            jnp.stack(colocate_parts(parts_s)),
            jnp.stack(colocate_parts(parts_i)), k,
        )
        if merge_span is not None:
            self.tracer.end(merge_span)
        return TopKResult(ms, mi, coverage, ranges)

    # ----------------------------------------------------------- internals
    def _query_shard(self, rs, s, phi_rows, k, exclude_mask, exclude_ids,
                     block_items, budget, indexes=None):
        """One shard's dispatch with failover + bounded deadline-aware
        retries. Returns (scores, ids) or None (shard unavailable for this
        request — the degradation path). ``indexes`` (IVF mode) swaps the
        exact slab sweep for the shard's index dispatch; every replica of
        a shard shares the index (replicas are bit-exact content copies),
        so the fault/stale/latency machinery wraps both paths identically."""
        spent = 0.0       # latency burned: real + injected + backoff
        attempt = 0
        tr = self.tracer
        while attempt < self.retry.max_attempts:
            live = rs.live(s)
            if not live:
                return None
            attempt += 1
            rep = rs.pick(s)
            rep.outstanding += 1
            sp = None
            if tr is not None:
                sp = tr.begin("dispatch", shard=s, replica=rep.idx,
                              attempt=attempt)
            t0 = self.clock()
            try:
                if self.injector is not None:
                    self.injector.before_dispatch(s, rep.idx)
                if rep.version != rs.version:
                    raise StaleReplicaError(
                        f"replica ({s}, {rep.idx}) serves table v"
                        f"{rep.version}, live is v{rs.version}"
                    )
                if indexes is not None:
                    if indexes[s] is None:   # shard owns no valid rows
                        ss, ii = empty_topk(int(phi_rows.shape[0]), k)
                    else:
                        ss, ii = indexes[s].topk(
                            phi_rows, k, exclude_ids=exclude_ids,
                            registry=self.registry,
                        )
                else:
                    self._costs.record_topk(
                        int(phi_rows.shape[0]), rs.table.rows_per,
                        int(rep.slab.shape[1]), k,
                        excl_l=0 if exclude_ids is None
                        else int(exclude_ids.shape[1]),
                    )
                    ss, ii = shard_topk(
                        rs.table, s, phi_rows, k, slab=rep.slab,
                        exclude_mask=exclude_mask, exclude_ids=exclude_ids,
                        block_items=block_items,
                    )
                lat = self.clock() - t0
                self.monitor.observe(rep.key, lat)
                self._replica_latency(s, rep.idx).observe(lat)
                rep.served += 1
                rep.failures = 0
                self._m["dispatches"].inc()
                if sp is not None:
                    tr.end(sp, outcome="ok")
                return ss, ii
            except ReplicaFailure as e:
                lat = max(self.clock() - t0, e.latency)
                spent += lat
                self._m["dispatches"].inc()
                self._m["faults"].inc()
                # the satellite: burned deadline budget — real wall time
                # OR the injected fault's declared latency, whichever the
                # retry loop actually charged against the budget
                self._m["fault_burned_s"].inc(lat)
                if sp is not None:
                    tr.end(sp, outcome=type(e).__name__, burned_s=lat)
                rep.failures += 1
                if isinstance(e, ReplicaTimeout):
                    self.monitor.observe(rep.key, lat)
                    self._replica_latency(s, rep.idx).observe(lat)
                if rep.failures >= self.fail_threshold:
                    rs.mark_dead(s, rep.idx, reason=type(e).__name__)
                    self._m["replicas_died"].inc()
                    if self.auto_heal:
                        self.heal()
            finally:
                rep.outstanding -= 1
            # burned latency (real + injected) already exhausted the
            # budget: even a free failover dispatch would answer late
            if budget is not None and spent >= budget:
                self._m["deadline_gaveups"].inc()
                return None
            # failover beats backoff: another live replica is already warm
            if any(r.idx != rep.idx for r in rs.live(s)):
                self._m["failovers"].inc()
                if tr is not None:
                    tr.end(tr.begin("failover", shard=s,
                                    from_replica=rep.idx))
                continue
            # same (possibly healed) set again: exponential backoff, but
            # only if the sleep FITS the remaining deadline budget
            if attempt >= self.retry.max_attempts:
                break
            back = self.retry.backoff(attempt)
            if budget is not None:
                remaining = budget - spent
                if remaining <= 0.0 or back >= remaining:
                    self._m["deadline_gaveups"].inc()
                    return None
            self._m["retries"].inc()
            self._m["backoff_slept_s"].inc(back)
            if tr is not None:
                tr.end(tr.begin("retry", shard=s, backoff_s=back))
            spent += back
            self.sleep(back)
        return None

    # ----------------------------------------------------- staged rollout
    def begin_canary(self, psi_table: jax.Array) -> int:
        """Stage the next ψ table on ONE canary replica per shard (slot
        R, off the routing path). Readers keep hitting the live version;
        nothing observable changes until :meth:`promote_canary`. Returns
        the staged version number."""
        if self._canary is not None:
            raise RuntimeError(
                "a canary is already staged — promote or roll it back first"
            )
        rs = self._set.active
        staged = shard_psi(
            psi_table, self.n_shards, version=self.version + 1
        )
        self._canary = staged
        for s in range(staged.n_shards):
            slab = staged.shards[s]
            dev = rs._device_for(s, self.n_replicas)
            if dev is not None:
                slab = jax.device_put(slab, dev)
            rep = Replica(
                shard=s, idx=max(r.idx for r in rs.replicas[s]) + 1,
                slab=slab, device=dev, version=staged.version, canary=True,
            )
            rs.replicas[s].append(rep)
        self._m["canary_staged"].inc()
        return staged.version

    def canary_topk_phi(self, phi_rows, *, k=None,
                        exclude_ids=None) -> TopKResult:
        """Query the CANARY replicas only (mirrored traffic). Not routed
        to users; exists so the rollout can health-check the staged table
        under real query shapes before anyone sees it."""
        if self._canary is None:
            raise RuntimeError("no canary staged")
        staged = self._canary
        k = k or self.k
        phi_rows = jnp.asarray(phi_rows, jnp.float32)
        block_items = self.block_items
        if block_items is None:
            excl_l = 0 if exclude_ids is None else int(exclude_ids.shape[1])
            block_items = resolve_cluster_block_items(
                staged, int(phi_rows.shape[0]), k, excl_l=excl_l
            )
        parts_s, parts_i = [], []
        rs = self._set.active
        for s in range(staged.n_shards):
            canaries = [r for r in rs.replicas[s] if r.canary]
            slab = canaries[0].slab if canaries else staged.shards[s]
            ss, ii = shard_topk(
                staged, s, phi_rows, k, slab=slab, exclude_ids=exclude_ids,
                block_items=block_items,
            )
            parts_s.append(ss)
            parts_i.append(ii)
        if len(parts_s) == 1:
            return TopKResult(parts_s[0], parts_i[0])
        ms, mi = topk_merge_shards(
            jnp.stack(colocate_parts(parts_s)),
            jnp.stack(colocate_parts(parts_i)), k,
        )
        return TopKResult(ms, mi)

    def mirror_check(
        self,
        phi_rows: jax.Array,
        *,
        k: Optional[int] = None,
        validate: Optional[Callable[[TopKResult, TopKResult], bool]] = None,
    ) -> dict:
        """Health-check the canary under mirrored traffic: run ``phi_rows``
        against BOTH the live table and the canary replicas and judge the
        canary's answers. Built-in checks: well-formed shapes, no NaN, no
        +/-inf scores on admissible slots, ids in catalogue range.
        ``validate(live_result, canary_result)`` adds a caller policy
        (e.g. rank-overlap or quality thresholds). Returns a report dict;
        ``report["healthy"]`` is the promote/rollback verdict."""
        if self._canary is None:
            raise RuntimeError("no canary staged")
        k = k or self.k
        live_res = self.topk_phi(phi_rows, k=k)
        t0 = self.clock()
        canary_res = self.canary_topk_phi(phi_rows, k=k)
        latency = self.clock() - t0
        ids = np.asarray(canary_res.ids)
        scores = np.asarray(canary_res.scores)
        n_items = self._canary.n_items
        admissible = ids >= 0
        checks = {
            "shape_ok": ids.shape == np.asarray(live_res.ids).shape,
            "ids_in_range": bool(((ids >= -1) & (ids < n_items)).all()),
            "scores_finite": bool(
                np.isfinite(scores[admissible]).all()
                if admissible.any() else True
            ),
            "not_all_empty": bool(admissible.any()),
        }
        if validate is not None:
            checks["validate_ok"] = bool(validate(live_res, canary_res))
        report = {
            "healthy": all(checks.values()),
            "checks": checks,
            "staged_version": self._canary.version,
            "live_version": self.version,
            "mirror_rows": int(np.asarray(phi_rows).shape[0]),
            "canary_latency_s": latency,
        }
        return report

    def promote_canary(self) -> int:
        """Flip the staged table live everywhere: the canary slabs seed
        replica 0 and the remaining R−1 replicas are placed fresh — one
        atomic ReplicaSet swap, in-flight queries finish on the old
        snapshot (the drainless rollout)."""
        if self._canary is None:
            raise RuntimeError("no canary staged")
        staged = self._canary

        def build(version: int) -> ReplicaSet:
            table = PsiShardSet(
                shards=staged.shards, n_items=staged.n_items,
                rows_per=staged.rows_per, version=version,
            )
            return ReplicaSet(
                table, self.n_replicas, devices=self.devices,
                policy=self.policy,
            )

        version = self._set.publish(build)
        self._canary = None
        self._m["canary_promoted"].inc()
        self._m_version.set(version)
        return version

    def rollback_canary(self) -> None:
        """Drop the staged table: remove the canary replicas, keep serving
        the live version untouched — the no-downtime bad-table path."""
        if self._canary is None:
            raise RuntimeError("no canary staged")
        rs = self._set.active
        for s in range(rs.n_shards):
            rs.replicas[s] = [r for r in rs.replicas[s] if not r.canary]
        self._canary = None
        self._m["canary_rolled_back"].inc()
