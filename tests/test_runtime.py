"""Elastic mesh manager + straggler watchdog (single-device semantics;
multi-device elasticity is exercised in tests/test_distributed.py via a
subprocess with forced host devices)."""

from repro.runtime.elastic import ElasticMeshManager, largest_mesh_shape
from repro.runtime.health import StragglerWatchdog


def test_largest_mesh_shape():
    assert largest_mesh_shape(256, 16) == (16, 16)
    assert largest_mesh_shape(240, 16) == (15, 16)   # lost one host of 16
    assert largest_mesh_shape(250, 16) == (125, 2)   # degrade TP to keep chips
    assert largest_mesh_shape(7, 4) == (7, 1)
    assert largest_mesh_shape(512, 16) == (32, 16)


def test_manager_builds_mesh_single_device():
    mgr = ElasticMeshManager(model_axis=1)
    mesh = mgr.build()
    assert mesh.devices.size == 1
    assert mesh.axis_names == ("data", "model")


def test_watchdog_flags_persistent_straggler():
    wd = StragglerWatchdog(threshold=1.5, patience=2)
    for step in range(8):
        for host in range(4):
            wd.report(host, 1.0 if host != 2 else 3.0)
        flagged = wd.check()
    assert flagged == [2]


def test_watchdog_ignores_transient_blip():
    wd = StragglerWatchdog(threshold=1.5, patience=3)
    for step in range(8):
        for host in range(4):
            slow = host == 1 and step == 3   # one-off blip
            wd.report(host, 3.0 if slow else 1.0)
        flagged = wd.check()
    assert flagged == []
