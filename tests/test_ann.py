"""IVF approximate retrieval tier (serve/ann.py): oracle bit-identity at
n_probe >= n_clusters, quantized-storage parity, empty-cluster and
fully-pruned-exclusion edges, delta fold-in consistency, and the
retrieval='ivf' threading through engine / cluster / mesh."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quant import int8_dequantize_rows, int8_quantize_rows
from repro.eval.ranking import ann_recall_curve, overlap_recall
from repro.kernels.topk_score import topk_score
from repro.serve.ann import (
    AnnConfig,
    PsiIndex,
    build_shard_indexes,
    fold_delta_indexes,
    ivf_cluster_topk,
    kmeans,
)
from repro.serve.cluster import ShardedRetrievalCluster, shard_psi
from repro.serve.engine import RetrievalEngine
from repro.serve.mesh import FaultInjector, FaultTolerantRetrievalMesh


def _clustered(n, d, n_centers, seed=0, spread=4.0):
    """ψ with real cluster structure so pruning is meaningful."""
    rng = np.random.default_rng(seed)
    cents = rng.normal(size=(n_centers, d)) * spread
    per = -(-n // n_centers)
    rows = np.concatenate(
        [cents[i] + rng.normal(size=(per, d)) for i in range(n_centers)]
    )[:n]
    rng.shuffle(rows)
    return jnp.asarray(rows, jnp.float32)


def _queries(b, d, seed=100):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(b, d)), jnp.float32)


# ---------------------------------------------------------------- kmeans

def test_kmeans_shapes_and_empty_cluster_centroids():
    # more clusters than distinct directions -> some clusters go empty;
    # Lloyd must keep the old centroid, never emit NaN
    psi = jnp.asarray(np.repeat(np.eye(4, 8, dtype=np.float32), 10, axis=0))
    cents, assign = kmeans(psi, 16, n_iters=6, seed=3)
    assert cents.shape == (16, 8) and assign.shape == (40,)
    assert np.isfinite(np.asarray(cents)).all()
    assert np.asarray(assign).min() >= 0 and np.asarray(assign).max() < 16


# --------------------------------------------------- oracle bit-identity

@pytest.mark.parametrize("quant", ["none"])
def test_oracle_bit_identity_ids_and_scores(quant):
    psi = _clustered(400, 16, 8, seed=1)
    phi = _queries(9, 16)
    idx = PsiIndex.build(psi, AnnConfig(n_clusters=8, quant=quant, seed=2))
    es, ei = topk_score(phi, psi, 25)
    # n_probe == n_clusters AND n_probe > n_clusters both hit the oracle gate
    for p in (8, 11):
        s, i = idx.topk(phi, 25, n_probe=p)
        np.testing.assert_array_equal(np.asarray(i), np.asarray(ei))
        np.testing.assert_array_equal(np.asarray(s), np.asarray(es))


def test_oracle_bit_identity_with_exclusions():
    psi = _clustered(300, 8, 6, seed=4)
    phi = _queries(5, 8)
    ex = jnp.asarray(
        np.stack([np.arange(r, r + 40, dtype=np.int32) for r in range(5)])
    )
    idx = PsiIndex.build(psi, AnnConfig(n_clusters=6, seed=5))
    es, ei = topk_score(phi, psi, 10, exclude_ids=ex)
    s, i = idx.topk(phi, 10, n_probe=6, exclude_ids=ex)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ei))
    np.testing.assert_array_equal(np.asarray(s), np.asarray(es))


def test_pruned_recall_reasonable_and_monotone_probe_sweep():
    psi = _clustered(600, 16, 8, seed=6)
    phi = _queries(12, 16)
    idx = PsiIndex.build(psi, AnnConfig(n_clusters=8, seed=7))
    curve = ann_recall_curve(idx, phi, psi, k=20, n_probes=(1, 2, 4, 8))
    recalls = [pt["recall@20"] for pt in curve]
    assert recalls[-1] == 1.0          # oracle point closes the curve
    assert recalls[1] >= recalls[0] - 1e-9 or recalls[-1] >= recalls[0]
    assert recalls[1] > 0.3            # clustered data: 2/8 probes finds most


# ------------------------------------------------------------ exclusions

def test_exclude_ids_hitting_fully_pruned_blocks_is_harmless():
    # excluded ids live in clusters the query never probes: the exclusion
    # must neither crash nor change the candidates from probed blocks
    psi = _clustered(200, 8, 4, seed=8)
    phi = _queries(3, 8)
    idx = PsiIndex.build(psi, AnnConfig(n_clusters=4, seed=9))
    s0, i0 = idx.topk(phi, 5, n_probe=1)
    probed = set(np.asarray(i0).reshape(-1).tolist()) - {-1}
    unprobed = [g for g in range(200) if g not in probed][:8]
    ex = jnp.asarray(np.tile(np.asarray(unprobed, np.int32), (3, 1)))
    s1, i1 = idx.topk(phi, 5, n_probe=1, exclude_ids=ex)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i0))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s0))


def test_exclude_everything_probed_yields_sentinels():
    psi = _clustered(64, 8, 2, seed=10)
    phi = _queries(2, 8)
    idx = PsiIndex.build(psi, AnnConfig(n_clusters=2, seed=11))
    ex = jnp.asarray(np.tile(np.arange(64, dtype=np.int32), (2, 1)))
    s, i = idx.topk(phi, 4, n_probe=2, exclude_ids=ex)
    assert (np.asarray(i) == -1).all()
    assert np.isneginf(np.asarray(s)).all()


def test_out_of_range_exclude_ids_ignored():
    psi = _clustered(100, 8, 4, seed=12)
    phi = _queries(2, 8)
    idx = PsiIndex.build(psi, AnnConfig(n_clusters=4, seed=13))
    s0, i0 = idx.topk(phi, 6, n_probe=4)
    ex = jnp.asarray(np.full((2, 3), 10_000, np.int32))
    s1, i1 = idx.topk(phi, 6, n_probe=4, exclude_ids=ex)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i0))


# ---------------------------------------------------------- quantization

@pytest.mark.parametrize("quant", ["bf16", "int8"])
def test_quantized_index_matches_dequantized_oracle(quant):
    psi = _clustered(300, 16, 6, seed=14)
    phi = _queries(6, 16)
    idx = PsiIndex.build(psi, AnnConfig(n_clusters=6, quant=quant, seed=15))
    # oracle: exact dense top-K over the SAME lossy table the index stores
    if quant == "int8":
        deq = np.zeros((300, 16), np.float32)
        stored = int8_dequantize_rows(idx.psi_q, idx.scales)
    else:
        deq = np.zeros((300, 16), np.float32)
        stored = np.asarray(idx.psi_q, np.float32)
    live = np.asarray(idx.ids_global) >= 0
    deq[np.asarray(idx.ids_global)[live]] = np.asarray(stored)[live]
    es, ei = topk_score(phi, jnp.asarray(deq), 15)
    s, i = idx.topk(phi, 15, n_probe=6)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ei))
    np.testing.assert_allclose(np.asarray(s), np.asarray(es), rtol=1e-5, atol=1e-5)


def test_int8_scores_close_to_f32_relative():
    psi = _clustered(400, 32, 8, seed=16)
    phi = _queries(8, 32)
    exact_s, exact_i = topk_score(phi, psi, 10)
    idx = PsiIndex.build(psi, AnnConfig(n_clusters=8, quant="int8", seed=17))
    s, i = idx.topk(phi, 10, n_probe=8)
    assert overlap_recall(np.asarray(i), np.asarray(exact_i)) >= 0.9
    denom = np.maximum(np.abs(np.asarray(exact_s)), 1e-3)
    hit = np.asarray(i) == np.asarray(exact_i)
    rel = np.abs(np.asarray(s) - np.asarray(exact_s))[hit] / denom[hit]
    assert rel.max() < 0.05


def test_quantized_tie_stability_ascending_ids():
    # identical rows quantize to identical codes -> equal scores; the
    # two-key merge must still emit them in ascending GLOBAL id order
    row = np.random.default_rng(18).normal(size=16).astype(np.float32)
    psi = jnp.asarray(np.tile(row, (24, 1)))
    phi = jnp.asarray(row[None, :] * 0.5)
    for quant in ("none", "bf16", "int8"):
        idx = PsiIndex.build(psi, AnnConfig(n_clusters=3, quant=quant, seed=19))
        s, i = idx.topk(phi, 8, n_probe=3)
        ids = np.asarray(i)[0]
        assert (ids == np.arange(8)).all(), (quant, ids)
        assert np.allclose(np.asarray(s)[0], np.asarray(s)[0][0])


# ---------------------------------------------------------- delta fold-in

def test_apply_delta_patch_and_append_searchable():
    psi = _clustered(120, 8, 4, seed=20)
    idx = PsiIndex.build(psi, AnnConfig(n_clusters=4, seed=21, reindex_after=1000))
    rng = np.random.default_rng(22)
    patch_rows = jnp.asarray(rng.normal(size=(2, 8)) * 9, jnp.float32)
    idx2 = idx.apply_delta(patch_rows, np.asarray([5, 60], np.int64))
    assert idx2.staleness == idx.staleness + 2
    assert idx2.n_rows == 120
    # patched rows dominate in norm -> must be retrievable at their ids
    for r in range(2):
        q = patch_rows[r][None, :]
        _, i = idx2.topk(q, 1, n_probe=4)
        assert int(np.asarray(i)[0, 0]) == [5, 60][r]
    # appends: contiguous ids only
    app_rows = jnp.asarray(rng.normal(size=(3, 8)) * 9, jnp.float32)
    idx3 = idx2.apply_delta(app_rows, np.asarray([120, 121, 122], np.int64))
    assert idx3.n_rows == 123 and idx3.staleness == idx2.staleness + 3
    # oracle probe: the folded index over the appended catalogue must
    # bit-match the exact kernel over the equivalent dense table
    dense = np.asarray(psi).copy()
    dense[5], dense[60] = patch_rows[0], patch_rows[1]
    dense = np.concatenate([dense, np.asarray(app_rows)])
    _, ei = topk_score(app_rows, jnp.asarray(dense), 3)
    _, i = idx3.topk(app_rows, 3, n_probe=4)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ei))
    assert set(np.asarray(ei).reshape(-1)) & {120, 121, 122}
    with pytest.raises(ValueError):
        idx3.apply_delta(app_rows[:1], np.asarray([999], np.int64))  # hole


def test_apply_delta_oracle_identity_after_fold():
    psi = _clustered(150, 8, 4, seed=23)
    idx = PsiIndex.build(psi, AnnConfig(n_clusters=4, seed=24))
    rng = np.random.default_rng(25)
    rows = jnp.asarray(rng.normal(size=(4, 8)), jnp.float32)
    ids = np.asarray([0, 75, 150, 151], np.int64)       # patch + append mix
    idx2 = idx.apply_delta(rows, ids)
    dense = np.asarray(psi).copy()
    dense[0], dense[75] = rows[0], rows[1]
    dense = np.concatenate([dense, np.asarray(rows[2:])])
    phi = _queries(5, 8, seed=26)
    es, ei = topk_score(phi, jnp.asarray(dense), 12)
    s, i = idx2.topk(phi, 12, n_probe=4)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ei))
    np.testing.assert_array_equal(np.asarray(s), np.asarray(es))


def test_apply_delta_grows_full_block():
    # tiny catalogue, 1 cluster: block_rows starts at 8; 9th append must
    # trigger the +8-row repack and stay consistent
    psi = jnp.asarray(np.random.default_rng(27).normal(size=(8, 4)), jnp.float32)
    idx = PsiIndex.build(psi, AnnConfig(n_clusters=1, seed=28))
    assert idx.block_rows == 8
    rng = np.random.default_rng(29)
    rows = jnp.asarray(rng.normal(size=(3, 4)), jnp.float32)
    idx2 = idx.apply_delta(rows, np.asarray([8, 9, 10], np.int64))
    assert idx2.block_rows > 8 and idx2.n_rows == 11
    dense = np.concatenate([np.asarray(psi), np.asarray(rows)])
    phi = _queries(3, 4, seed=30)
    es, ei = topk_score(phi, jnp.asarray(dense), 6)
    s, i = idx2.topk(phi, 6, n_probe=1)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ei))


def test_needs_reindex_trigger():
    psi = _clustered(60, 8, 2, seed=31)
    idx = PsiIndex.build(psi, AnnConfig(n_clusters=2, seed=32, reindex_after=3))
    rows = jnp.asarray(np.random.default_rng(33).normal(size=(2, 8)), jnp.float32)
    idx2 = idx.apply_delta(rows, np.asarray([1, 2], np.int64))
    assert not idx2.needs_reindex()
    idx3 = idx2.apply_delta(rows, np.asarray([3, 4], np.int64))
    assert idx3.needs_reindex()      # 4 > reindex_after=3


# ------------------------------------------------------- sharded indexes

def test_sharded_indexes_match_exact_cluster_topk():
    psi = _clustered(250, 8, 6, seed=34)
    table = shard_psi(psi, 3)
    cfg = AnnConfig(n_clusters=4, seed=35)
    idxs = build_shard_indexes(table, cfg)
    phi = _queries(6, 8, seed=36)
    es, ei = topk_score(phi, psi, 14)
    res = ivf_cluster_topk(table, idxs, phi, 14, n_probe=4)
    np.testing.assert_array_equal(np.asarray(res.ids), np.asarray(ei))
    np.testing.assert_array_equal(np.asarray(res.scores), np.asarray(es))


def test_fold_delta_indexes_rebuilds_when_stale():
    psi = _clustered(90, 8, 3, seed=37)
    table = shard_psi(psi, 3)
    cfg = AnnConfig(n_clusters=2, seed=38, reindex_after=1)
    idxs = build_shard_indexes(table, cfg)
    rows = jnp.asarray(np.random.default_rng(39).normal(size=(2, 8)), jnp.float32)
    ids = np.asarray([0, 40], np.int64)          # shards 0 and 1
    from repro.serve.publish import apply_delta
    table2 = shard_psi(jnp.asarray(apply_delta(np.asarray(psi), rows, ids)), 3)
    idxs2 = fold_delta_indexes(idxs, table2, rows, ids, cfg)
    # reindex_after=1 < 2 folded ids -> touched shards rebuilt fresh
    assert not idxs2[0].needs_reindex() and not idxs2[1].needs_reindex()
    # regardless of fold-vs-rebuild, results must match the exact table
    phi = _queries(4, 8, seed=40)
    dense = np.asarray(psi).copy()
    dense[0], dense[40] = rows[0], rows[1]
    es, ei = topk_score(phi, jnp.asarray(dense), 9)
    res = ivf_cluster_topk(table2, idxs2, phi, 9, n_probe=2)
    np.testing.assert_array_equal(np.asarray(res.ids), np.asarray(ei))


# ----------------------------------------------------- serving-tier wiring

def test_engine_ivf_oracle_matches_exact_engine():
    psi = _clustered(200, 8, 4, seed=41)
    phi = _queries(5, 8, seed=42)
    ex = RetrievalEngine(psi, lambda q: q, k=12)
    iv = RetrievalEngine(psi, lambda q: q, k=12, retrieval="ivf",
                         ann=AnnConfig(n_clusters=4, n_probe=4, seed=43))
    a, b = ex.topk_phi(phi), iv.topk_phi(phi)
    np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(b.ids))
    np.testing.assert_array_equal(np.asarray(a.scores), np.asarray(b.scores))
    with pytest.raises(ValueError):
        iv.topk_phi(phi, exclude_mask=jnp.zeros((5, 200), bool))
    with pytest.raises(ValueError):
        RetrievalEngine(psi, lambda q: q, retrieval="hnsw")


def test_cluster_ivf_publish_delta_and_exclusions():
    psi = _clustered(240, 8, 4, seed=44)
    phi = _queries(6, 8, seed=45)
    cfg = AnnConfig(n_clusters=4, n_probe=4, seed=46)
    cl_ex = ShardedRetrievalCluster(n_shards=3, k=10)
    cl_iv = ShardedRetrievalCluster(n_shards=3, k=10, retrieval="ivf", ann=cfg)
    cl_ex.publish(psi)
    cl_iv.publish(psi)
    rows = jnp.asarray(np.random.default_rng(47).normal(size=(3, 8)), jnp.float32)
    ids = np.asarray([2, 100, 210], np.int64)
    cl_ex.publish_delta(rows, ids)
    cl_iv.publish_delta(rows, ids)
    eids = jnp.asarray(np.tile(np.arange(20, dtype=np.int32), (6, 1)))
    a = cl_ex.topk_phi(phi, exclude_ids=eids)
    b = cl_iv.topk_phi(phi, exclude_ids=eids)
    np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(b.ids))
    np.testing.assert_array_equal(np.asarray(a.scores), np.asarray(b.scores))
    with pytest.raises(ValueError):
        cl_iv.topk_phi(phi, exclude_mask=jnp.zeros((6, 243), bool))


def test_mesh_ivf_matches_exact_and_survives_faults():
    psi = _clustered(180, 8, 3, seed=48)
    phi = _queries(4, 8, seed=49)
    cfg = AnnConfig(n_clusters=3, n_probe=3, seed=50)
    m_ex = FaultTolerantRetrievalMesh(n_shards=3, n_replicas=2, k=8)
    m_iv = FaultTolerantRetrievalMesh(n_shards=3, n_replicas=2, k=8,
                                      retrieval="ivf", ann=cfg)
    m_ex.publish(psi)
    m_iv.publish(psi)
    a, b = m_ex.topk_phi(phi), m_iv.topk_phi(phi)
    np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(b.ids))
    np.testing.assert_array_equal(np.asarray(a.scores), np.asarray(b.scores))
    assert b.coverage == 1.0
    # kill one replica of shard 0: the other replica must still serve ivf
    inj = FaultInjector()
    inj.fail(0, 0, "error")
    m_f = FaultTolerantRetrievalMesh(n_shards=3, n_replicas=2, k=8,
                                     retrieval="ivf", ann=cfg, injector=inj)
    m_f.publish(psi)
    c = m_f.topk_phi(phi)
    np.testing.assert_array_equal(np.asarray(c.ids), np.asarray(a.ids))
    assert c.coverage == 1.0


def test_empty_shard_index_is_none_and_served_as_empty():
    # 5 shards over 90 rows with rows_per=30 -> shards 3,4 are all padding
    psi = _clustered(90, 8, 3, seed=51)
    table = shard_psi(psi, 5)
    if any(table.valid_rows(s) == 0 for s in range(table.n_shards)):
        idxs = build_shard_indexes(table, AnnConfig(n_clusters=2, seed=52))
        assert any(ix is None for ix in idxs)
        phi = _queries(3, 8, seed=53)
        es, ei = topk_score(phi, psi, 7)
        res = ivf_cluster_topk(table, idxs, phi, 7, n_probe=2)
        np.testing.assert_array_equal(np.asarray(res.ids), np.asarray(ei))


# ------------------------------------------------------ quant.py helpers

def test_quant_rows_roundtrip_and_shapes():
    x = np.random.default_rng(54).normal(size=(10, 6)).astype(np.float32)
    x[3] *= 100.0    # per-row scales must absorb wildly different norms
    q, s = int8_quantize_rows(jnp.asarray(x))
    assert q.shape == (10, 6) and q.dtype == jnp.int8 and s.shape == (10,)
    back = np.asarray(int8_dequantize_rows(q, s))
    rel = np.abs(back - x).max(axis=1) / np.abs(x).max(axis=1)
    assert rel.max() < 0.01
    with pytest.raises(ValueError):
        int8_quantize_rows(jnp.zeros((4,)))
