"""Elastic mesh manager + straggler watchdog (single-device semantics;
multi-device elasticity is exercised in tests/test_distributed.py via a
subprocess with forced host devices)."""

import pytest

from repro.runtime.elastic import ElasticMeshManager, largest_mesh_shape
from repro.runtime.health import StragglerWatchdog


def test_largest_mesh_shape():
    assert largest_mesh_shape(256, 16) == (16, 16)
    assert largest_mesh_shape(240, 16) == (15, 16)   # lost one host of 16
    assert largest_mesh_shape(250, 16) == (25, 10)   # largest divisor <= 16
    assert largest_mesh_shape(7, 4) == (7, 1)
    assert largest_mesh_shape(512, 16) == (32, 16)


def test_largest_mesh_shape_non_power_of_two_divisors():
    """The halving-chain bug: model //= 2 skipped every non-power-of-two
    divisor. The shrink must land on the LARGEST divisor of n_devices that
    fits the requested model axis."""
    assert largest_mesh_shape(8, 6) == (2, 4)     # was (8, 1)
    assert largest_mesh_shape(12, 6) == (2, 6)
    assert largest_mesh_shape(18, 12) == (2, 9)   # 9 is odd: unreachable by /2
    assert largest_mesh_shape(15, 6) == (3, 5)
    assert largest_mesh_shape(100, 48) == (4, 25)


def test_largest_mesh_shape_edge_cases():
    assert largest_mesh_shape(1, 16) == (1, 1)
    assert largest_mesh_shape(5, 1) == (5, 1)
    assert largest_mesh_shape(13, 13) == (1, 13)   # prime: whole axis fits
    assert largest_mesh_shape(13, 12) == (13, 1)   # prime, capped: no divisor
    assert largest_mesh_shape(6, 0) == (6, 1)      # degenerate axis request
    with pytest.raises(ValueError):
        largest_mesh_shape(0, 4)


def test_manager_builds_mesh_single_device():
    mgr = ElasticMeshManager(model_axis=1)
    mesh = mgr.build()
    assert mesh.devices.size == 1
    assert mesh.axis_names == ("data", "model")


def test_watchdog_flags_persistent_straggler():
    wd = StragglerWatchdog(threshold=1.5, patience=2)
    for step in range(8):
        for host in range(4):
            wd.report(host, 1.0 if host != 2 else 3.0)
        flagged = wd.check()
    assert flagged == [2]


def test_watchdog_ignores_transient_blip():
    wd = StragglerWatchdog(threshold=1.5, patience=3)
    for step in range(8):
        for host in range(4):
            slow = host == 1 and step == 3   # one-off blip
            wd.report(host, 3.0 if slow else 1.0)
        flagged = wd.check()
    assert flagged == []


def test_watchdog_true_median_even_window():
    """Even-length windows must use the true median (mean of the middle
    pair), not the upper-middle element — the old bias inflated the fleet
    baseline and hid real stragglers behind it."""
    wd = StragglerWatchdog(threshold=1.4, patience=1, window=4)
    # host 0: [1, 1, 1, 3] -> true median 1.0 (upper-middle would say 1.0)
    # host 1: [1, 1, 3, 3] -> true median 2.0 (upper-middle would say 3.0)
    # host 2: [1, 1, 1, 1] -> 1.0
    for t in (1.0, 1.0, 1.0, 3.0):
        wd.report(0, t)
    for t in (1.0, 1.0, 3.0, 3.0):
        wd.report(1, t)
    for t in (1.0, 1.0, 1.0, 1.0):
        wd.report(2, t)
    # fleet median of {1.0, 2.0, 1.0} = 1.0; host 1 at 2.0 > 1.4x -> flagged
    assert wd.check() == [1]
    assert wd._median([1.0, 3.0]) == 2.0
    assert wd._median([1.0, 2.0, 4.0]) == 2.0


def test_watchdog_quiet_host_stops_voting():
    """A host whose history went quiet must not keep getting flagged (or
    keep dragging the fleet baseline) on stale entries forever."""
    wd = StragglerWatchdog(threshold=1.5, patience=2, window=4)
    for _ in range(6):
        for host in range(4):
            wd.report(host, 5.0 if host == 3 else 1.0)
        flagged = wd.check()
    assert flagged == [3]
    # host 3 goes silent (crashed / evicted); the others keep reporting
    for _ in range(wd.window * 4 + 1):
        for host in range(3):
            wd.report(host, 1.0)
    assert 3 not in wd.check()      # stale history no longer votes
    assert wd.strikes[3] == 0       # and its strikes reset
    # when it comes back slow it must re-earn the flag from fresh data
    for _ in range(2):
        for host in range(3):
            wd.report(host, 1.0)
        wd.report(3, 9.0)
    for _ in range(2):
        for host in range(3):
            wd.report(host, 1.0)
        wd.report(3, 9.0)
        flagged = wd.check()
    assert flagged == [3]


def test_watchdog_accepts_tuple_keys():
    """The serving mesh reports per-replica latencies under (shard,
    replica) tuple keys — the watchdog must be key-agnostic."""
    wd = StragglerWatchdog(threshold=1.5, patience=1, window=8)
    for _ in range(4):
        for key in ((0, 0), (0, 1), (1, 0), (1, 1)):
            wd.report(key, 4.0 if key == (1, 0) else 1.0)
    assert wd.check() == [(1, 0)]
