"""Training loop with checkpointing, fault tolerance and straggler hooks."""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, Iterator, Optional

import jax

from repro.checkpoint import Checkpointer
from repro.runtime.health import StragglerWatchdog


class Trainer:
    def __init__(
        self,
        step_fn: Callable,
        state,
        data_iter: Iterator[Dict],
        checkpointer: Optional[Checkpointer] = None,
        ckpt_every: int = 100,
        watchdog: Optional[StragglerWatchdog] = None,
        log_every: int = 10,
        log_fn: Callable[[str], None] = print,
    ):
        self.step_fn = step_fn
        self.state = state
        self.data_iter = data_iter
        self.checkpointer = checkpointer
        self.ckpt_every = ckpt_every
        self.watchdog = watchdog or StragglerWatchdog()
        self.log_every = log_every
        self.log_fn = log_fn
        self.metrics_history = []

    def maybe_resume(self) -> int:
        """Resume from the latest valid checkpoint if one exists."""
        if self.checkpointer is None:
            return 0
        step, restored = self.checkpointer.restore_latest(self.state)
        if restored is not None:
            self.state = restored
            self.log_fn(f"[trainer] resumed from step {step}")
            return int(step)
        return 0

    def run(self, n_steps: int) -> Any:
        start = self.maybe_resume()
        for i in range(start, n_steps):
            batch = next(self.data_iter)
            t0 = time.perf_counter()
            self.state, metrics = self.step_fn(self.state, batch)
            metrics = {k: float(v) for k, v in metrics.items()}
            dt = time.perf_counter() - t0
            self.watchdog.report(jax.process_index(), dt)
            self.metrics_history.append(metrics)
            if (i + 1) % self.log_every == 0:
                self.log_fn(
                    f"[trainer] step {i + 1} "
                    + " ".join(f"{k}={v:.4g}" for k, v in metrics.items())
                    + f" ({dt * 1e3:.1f} ms)"
                )
            if self.checkpointer and (i + 1) % self.ckpt_every == 0:
                self.checkpointer.save(i + 1, self.state)
            flagged = self.watchdog.check()
            if flagged:
                self.log_fn(f"[trainer] stragglers flagged: {flagged} "
                            "(would trigger elastic re-mesh on a pod)")
        if self.checkpointer:
            self.checkpointer.save(n_steps, self.state, blocking=True)
        return self.state
