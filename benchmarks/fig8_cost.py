"""Figure 8: conventional CD vs iCD training cost.

Two parts:
  1. MEASURED wall-time on a downscaled problem (CPU): one epoch of
     conventional dense CD (repro.core.naive_cd) vs one iCD epoch, same
     model, same data — validates the analytic cost model's slope.
  2. ANALYTIC FLOPs at the paper's scale (|C|=200k, |I|=68k, k=128) for the
     three context choices of Figure 8 (P, A, A+P+H feature sets) — the
     paper reports ~4 orders of magnitude; we reproduce the ratio from the
     complexity formulas O(|C||I|k) vs O(k²·N_Z + k·|S|).
"""
from __future__ import annotations

import time
from typing import Dict

import jax
import numpy as np

from repro.core import naive_cd
from repro.core.models import mf
from repro.sparse.interactions import build_interactions

PAPER = dict(n_ctx=200_000, n_items=68_000, events=2_000_000)
# N_Z(X) per context row for the three Figure-8 feature sets
FEATURES_NZ = {"P": 1, "A": 4, "A+P+H": 1 + 4 + 1 + 10}


def analytic_ratios() -> Dict[str, Dict[str, float]]:
    """FLOPs(conventional CD) / FLOPs(iCD) per epoch at paper scale.

    The ratio scales as ≈ |I|/k when the context side dominates: the paper's
    "four orders of magnitude" (Fig. 8, log scale) corresponds to the small
    embedding sizes typical for implicit feedback (k≈16 ⇒ 68000/16 ≈ 4·10³–
    10⁴ depending on feature set); at k=128 it is ~500×. We report the
    sweep — the paper does not state its k.
    """
    c, i, s = PAPER["n_ctx"], PAPER["n_items"], PAPER["events"]
    out = {}
    for k in (16, 32, 128):
        ratios = {}
        for feats, nz_row in FEATURES_NZ.items():
            # conventional CD on S_impl: every (c,i) cell is a training
            # example with nz_row + 1 active features → O(N_Z(X_impl)·k) [11]
            conv = 2.0 * c * i * (nz_row + 1) * k
            # iCD: implicit O(k²·(N_Z(X)+N_Z(Z))) + explicit O(k·|S|·nz)
            icd = 2.0 * (k * k * (c * nz_row + i) + k * s * (nz_row + 1))
            ratios[feats] = conv / icd
        out[f"k={k}"] = ratios
    return out


def measured_ratio(n_ctx=96, n_items=64, k=16, nnz=512, epochs=3, seed=0):
    """Wall-time ratio on a problem small enough to run the dense solver."""
    rng = np.random.default_rng(seed)
    cells = rng.choice(n_ctx * n_items, nnz, replace=False)
    ctx, item = cells // n_items, cells % n_items
    alpha0 = 0.5
    data = build_interactions(ctx, item, np.ones(nnz), np.full(nnz, 2.5),
                              n_ctx, n_items, alpha0=alpha0)
    y_dense, a_dense = naive_cd.dense_from_observed(
        jax.numpy.asarray(ctx), jax.numpy.asarray(item),
        jax.numpy.ones(nnz), jax.numpy.full((nnz,), 2.5), n_ctx, n_items, alpha0)
    hp = mf.MFHyperParams(k=k, alpha0=alpha0, l2=0.05)
    params = mf.init(jax.random.PRNGKey(0), n_ctx, n_items, k)

    # warmup/compile both paths
    e = mf.residuals(params, data)
    mf.epoch(params, data, e, hp)[0].w.block_until_ready()
    naive_cd.epoch_dense(params, y_dense, a_dense, hp).w.block_until_ready()

    t0 = time.perf_counter()
    p1, e1 = params, e
    for _ in range(epochs):
        p1, e1 = mf.epoch(p1, data, e1, hp)
    p1.w.block_until_ready()
    t_icd = (time.perf_counter() - t0) / epochs

    t0 = time.perf_counter()
    p2 = params
    for _ in range(epochs):
        p2 = naive_cd.epoch_dense(p2, y_dense, a_dense, hp)
    p2.w.block_until_ready()
    t_conv = (time.perf_counter() - t0) / epochs

    flops_ratio = naive_cd.flops_per_epoch_dense(n_ctx, n_items, k) / \
        naive_cd.flops_per_epoch_icd(n_ctx, n_items, nnz, k)
    return {
        "t_icd_s": t_icd, "t_conv_s": t_conv,
        "measured_ratio": t_conv / t_icd,
        "analytic_ratio_at_this_scale": flops_ratio,
    }


def run(quick: bool = False) -> Dict:
    """Analytic paper-scale ratios + a measured size sweep showing the gap
    growing ∝|C||I| exactly as the complexity analysis predicts (the small
    sizes are overhead-bound on CPU; the trend is the evidence)."""
    res = {"analytic_paper_scale": analytic_ratios()}
    sizes = ((64, 48), (192, 128)) if quick else ((64, 48), (256, 128), (512, 384), (1024, 512))
    sweep = {}
    for n_ctx, n_items in sizes:
        nnz = max(128, int(0.02 * n_ctx * n_items))
        sweep[f"{n_ctx}x{n_items}"] = measured_ratio(
            n_ctx=n_ctx, n_items=n_items, nnz=nnz, epochs=2 if quick else 4,
        )
    res["measured_size_sweep"] = sweep
    return res


if __name__ == "__main__":
    import json

    print(json.dumps(run(), indent=1))
