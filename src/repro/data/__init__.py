from repro.data.synthetic import (  # noqa: F401
    SyntheticImplicitDataset,
    make_implicit_dataset,
)
from repro.data.loader import interaction_stream, sharded_batches  # noqa: F401
