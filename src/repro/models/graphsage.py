"""GraphSAGE (Hamilton et al., arXiv:1706.02216) — mean aggregator.

Three execution modes matching the assigned shapes:
  * ``full``      — full-batch message passing over an edge list via
                    ``segment_sum`` (full_graph_sm, ogb_products)
  * ``minibatch`` — sampled fanout frontiers from ``repro.sparse.sampler``
                    (minibatch_lg: Reddit, fanout 15-10)
  * ``batched``   — dense small graphs (molecule: (B, 30, F) + adjacency)

Layer: h' = ReLU(W_self·h + W_neigh·mean_N(h))  (+ optional l2-normalize),
final linear classifier. The unsupervised ⟨z_u,z_v⟩ objective is
128-separable — see ``icd_link_loss`` (DESIGN.md §4: the one assigned arch
where the paper's technique applies directly).
"""
from __future__ import annotations

from typing import Dict, Sequence

import jax
import jax.numpy as jnp

from repro.configs.base import GNNConfig
from repro.core.gram import gram
from repro.models.common import dense_init
from repro.sparse.segment import segment_mean


def init_params(key, cfg: GNNConfig, d_feat: int) -> Dict:
    dims = [d_feat] + [cfg.d_hidden] * cfg.n_layers
    layers = []
    keys = jax.random.split(key, cfg.n_layers + 1)
    for i in range(cfg.n_layers):
        k1, k2 = jax.random.split(keys[i])
        layers.append({
            "w_self": dense_init(k1, (dims[i], dims[i + 1])),
            "w_neigh": dense_init(k2, (dims[i], dims[i + 1])),
            "b": jnp.zeros((dims[i + 1],)),
        })
    return {
        "layers": layers,
        "cls": dense_init(keys[-1], (cfg.d_hidden, cfg.n_classes)),
    }


def _layer(p, h_self, h_neigh_mean, final: bool):
    out = h_self @ p["w_self"] + h_neigh_mean @ p["w_neigh"] + p["b"]
    return out if final else jax.nn.relu(out)


# --------------------------------------------------------------- full ----
def forward_full(cfg: GNNConfig, params, feats: jax.Array, edges: jax.Array):
    """feats (N, F); edges (E, 2) [src → dst messages]."""
    n = feats.shape[0]
    h = feats
    for i, p in enumerate(params["layers"]):
        msgs = jnp.take(h, edges[:, 0], axis=0)
        neigh = segment_mean(msgs, edges[:, 1], n)
        h = _layer(p, h, neigh, final=False)
    return h @ params["cls"], h


# ---------------------------------------------------------- minibatch ----
def forward_minibatch(cfg: GNNConfig, params, frontier_feats: Sequence[jax.Array]):
    """frontier_feats[h]: features of the h-hop frontier, shapes
    (B·Πf_i, F) per ``repro.sparse.sampler.neighbor_sampler``."""
    hs = list(frontier_feats)
    n_layers = cfg.n_layers
    for i, p in enumerate(params["layers"]):
        new_hs = []
        for depth in range(n_layers - i):
            parent = hs[depth]
            child = hs[depth + 1]
            fanout = child.shape[0] // parent.shape[0]
            neigh = jnp.mean(
                child.reshape(parent.shape[0], fanout, child.shape[-1]), axis=1
            )
            new_hs.append(_layer(p, parent, neigh, final=False))
        hs = new_hs
    return hs[0] @ params["cls"], hs[0]


# ------------------------------------------------------------- batched ----
def forward_batched(cfg: GNNConfig, params, feats: jax.Array, adj: jax.Array):
    """feats (B, n, F), adj (B, n, n) row-normalized → logits per graph."""
    h = feats
    for p in params["layers"]:
        neigh = jnp.einsum("bnm,bmf->bnf", adj, h)
        h = _layer(p, h, neigh, final=False)
    pooled = jnp.mean(h, axis=1)
    return pooled @ params["cls"], pooled


def ce_loss(logits: jax.Array, labels: jax.Array, mask=None) -> jax.Array:
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1)
    return jnp.mean(nll)


# -------------------------------------------------- iCD link prediction ----
def icd_link_loss(z: jax.Array, pos_edges: jax.Array, alpha0: float = 0.1):
    """Unsupervised GraphSAGE objective with the paper's EXACT implicit
    negative term instead of negative sampling:

        Σ_{(u,v)∈E} (⟨z_u,z_v⟩ − 1)² + α₀ Σ_{u,v∈V×V} ⟨z_u,z_v⟩²

    The all-pairs term is Lemma 2 applied with Φ = Ψ = Z: Σ (JᵀJ-style)
    = Σ_{f,f'} J(f,f')² with J = ZᵀZ — O(N k²) instead of O(N²k)."""
    zu = jnp.take(z, pos_edges[:, 0], axis=0)
    zv = jnp.take(z, pos_edges[:, 1], axis=0)
    pos = jnp.sum((jnp.sum(zu * zv, -1) - 1.0) ** 2)
    j = gram(z)
    return pos + alpha0 * jnp.sum(j * j)
