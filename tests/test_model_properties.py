"""Model-level invariants (property tests on the transformer + kernels path).

The LM configs are the shared inline smoke-scale ``LMConfig``s from
``tests/_smoke_configs.py`` (the seed-template registry configs were
removed in PR 4)."""
import jax
import jax.numpy as jnp
import numpy as np
from _smoke_configs import GEMMA_SMOKE, GQA_SMOKE, QWEN_SMOKE

from repro.core.models import mf
from repro.models import transformer as T
from repro.sparse.interactions import build_interactions


def test_causality_future_tokens_do_not_affect_past_logits():
    """Changing token t must not change logits at positions < t (causal
    mask + rolling local windows)."""
    cfg = GEMMA_SMOKE  # exercises local+global alternation
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 12), 0, cfg.vocab)
    toks2 = toks.at[0, 7].set((toks[0, 7] + 1) % cfg.vocab)
    l1, _ = T.forward(cfg, params, toks, compute_dtype=jnp.float32)
    l2, _ = T.forward(cfg, params, toks2, compute_dtype=jnp.float32)
    np.testing.assert_allclose(l1[:, :7], l2[:, :7], rtol=1e-5, atol=1e-5)
    assert not np.allclose(l1[:, 7:], l2[:, 7:], atol=1e-5)


def test_scan_vs_unrolled_layers_identical():
    """cfg.scan_layers must be a pure compilation choice."""
    cfg = QWEN_SMOKE
    import dataclasses

    cfg_u = dataclasses.replace(cfg, scan_layers=False)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 8), 0, cfg.vocab)
    l1, _ = T.forward(cfg, params, toks, compute_dtype=jnp.float32)
    l2, _ = T.forward(cfg_u, params, toks, compute_dtype=jnp.float32)
    np.testing.assert_allclose(l1, l2, rtol=1e-5, atol=1e-5)


def test_moe_aux_loss_balanced_router_is_minimal():
    """A perfectly uniform router gives aux loss == 1 (the Switch minimum)."""
    from repro.configs.base import LMConfig, MoEConfig
    from repro.models.transformer import _moe_ffn

    cfg = LMConfig(
        name="t", n_layers=1, d_model=16, n_heads=2, n_kv_heads=2, head_dim=8,
        d_ff=32, vocab=17, moe=MoEConfig(n_experts=4, top_k=2, d_expert=16),
    )
    key = jax.random.PRNGKey(0)
    p = {
        "router": jnp.zeros((16, 4)),  # uniform routing
        "e_gate": 0.1 * jax.random.normal(key, (4, 16, 16)),
        "e_up": 0.1 * jax.random.normal(key, (4, 16, 16)),
        "e_down": 0.1 * jax.random.normal(key, (4, 16, 16)),
    }
    h = jax.random.normal(jax.random.PRNGKey(1), (64, 16))
    out, aux = _moe_ffn(cfg, p, h)
    assert out.shape == (64, 16)
    # ties broken deterministically; probs uniform ⇒ E·Σ f·P == E·(1/E) == 1
    np.testing.assert_allclose(float(aux), 1.0, rtol=1e-5)


def test_mf_epoch_pallas_gram_matches_xla():
    """hp.implementation='pallas' routes J through the Pallas gram kernel
    (interpret mode on CPU) — must be trajectory-identical."""
    rng = np.random.default_rng(0)
    n_ctx, n_items, nnz, k = 20, 15, 80, 4
    cells = rng.choice(n_ctx * n_items, nnz, replace=False)
    data = build_interactions(cells // n_items, cells % n_items,
                              np.ones(nnz), np.full(nnz, 2.0),
                              n_ctx, n_items, alpha0=0.5)
    params = mf.init(jax.random.PRNGKey(0), n_ctx, n_items, k)
    e = mf.residuals(params, data)

    hp_x = mf.MFHyperParams(k=k, alpha0=0.5, l2=0.05, implementation="xla")
    hp_p = mf.MFHyperParams(k=k, alpha0=0.5, l2=0.05, implementation="pallas")
    px, _ = mf.epoch(params, data, e, hp_x)
    pp, _ = mf.epoch(params, data, e, hp_p)
    np.testing.assert_allclose(px.w, pp.w, rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(px.h, pp.h, rtol=2e-4, atol=2e-5)


def test_decode_cache_isolation_between_batch_rows():
    """Decode rows must not leak state across the batch dimension."""
    cfg = GQA_SMOKE
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    cache = T.init_cache(cfg, 2, 8, dtype=jnp.float32)
    t_a = jnp.asarray([[3], [9]], jnp.int32)
    logits, _ = T.decode_step(cfg, params, cache, t_a, jnp.int32(0),
                              compute_dtype=jnp.float32)
    cache1 = T.init_cache(cfg, 1, 8, dtype=jnp.float32)
    solo, _ = T.decode_step(cfg, params, cache1, t_a[:1], jnp.int32(0),
                            compute_dtype=jnp.float32)
    np.testing.assert_allclose(logits[0], solo[0], rtol=1e-4, atol=1e-4)
