"""Mixed-precision optimizer wrapper — the ZeRO-1 building block.

Live parameters stay bf16 (replicated over the data axis); the fp32 master
copy lives INSIDE the optimizer state, which the launch layer shards over
(data × model). One step:

    grads(bf16) ──clip──► inner.update on fp32 master (data-sharded math)
    master += updates;  params_delta = master.astype(bf16) − params

GSPMD then emits exactly the ZeRO-1 schedule: a single gradient all-reduce,
sharded optimizer math, and one all-gather of the updated bf16 parameters —
replacing ZeRO-3's per-layer-per-microbatch parameter all-gathers
(hillclimb #2, EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.optim.base import OptimizerDef, apply_updates


def mixed_precision(inner: OptimizerDef) -> OptimizerDef:
    def init(params):
        master = jax.tree_util.tree_map(
            lambda p: p.astype(jnp.float32), params
        )
        return {"master": master, "inner": inner.init(master)}

    def update(grads, state, params):
        upd, inner_state = inner.update(grads, state["inner"], state["master"])
        master = apply_updates(state["master"], upd)
        delta = jax.tree_util.tree_map(
            lambda m, p: m.astype(p.dtype) - p, master, params
        )
        return delta, {"master": master, "inner": inner_state}

    return OptimizerDef(init, update)
