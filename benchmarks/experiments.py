"""Paper §6 experiment reproductions on the synthetic YouTube-like dataset.

Protocols (paper §6.2):
  * Cold-Start  — hold out whole users; recommend from attributes only.
  * Offline     — hold out each user's LAST event (leave-one-out).
  * Instant     — global time cutoff; model frozen, features keep updating.

Models: Popularity, Coview, iCD-MF, iCD-FM with feature sets
A (age/country/gender/device), P (previous video), U (user id),
H (watch history), and combinations — exactly Figure 6/7's lineup.

Everything is sized to run on CPU in minutes; the mechanisms the paper
claims (attributes carry cold-start, P/H carry sequence signal, combined
features win) are generated into the data (see repro.data.synthetic).
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Dict, List, Sequence, Tuple

import jax
import numpy as np

from repro.core.design import Design, make_design
from repro.core.metrics import recall_ndcg_multi
from repro.core.models import fm, mf
from repro.data.synthetic import make_implicit_dataset
from repro.sparse.interactions import build_interactions

K_EVAL = 100
NO_PREV = 0  # reserved "no previous video" id (item ids shift by +1)
HIST_LEN = 10


def paper_dataset(quick: bool = False, seed: int = 0):
    """The §6 stand-in: cardinalities scaled to CPU, signal structure tuned
    so the paper's qualitative orderings are generated into the data
    (attributes carry cold users, sequences carry P/H — see
    repro/data/synthetic.py)."""
    if quick:
        return make_implicit_dataset(
            n_users=800, n_items=1500, attr_strength=0.95,
            pop_strength=0.4, taste_strength=2.5, markov_strength=1.2,
            seed=seed,
        )
    return make_implicit_dataset(
        n_users=2500, n_items=3000, attr_strength=0.95,
        pop_strength=0.4, taste_strength=2.5, markov_strength=1.2,
        events_per_user=(8, 40), seed=seed,
    )


# ---------------------------------------------------------------------------
# feature building
# ---------------------------------------------------------------------------
def _merge_bag(items: Sequence[int], length: int) -> Tuple[np.ndarray, np.ndarray]:
    """Last ``length`` items as a unique-id weighted bag (merge repeats)."""
    recent = list(items)[-length:]
    if not recent:
        return np.zeros(length, np.int64), np.zeros(length, np.float32)
    w = 1.0 / len(recent)
    acc: Dict[int, float] = defaultdict(float)
    for it in recent:
        acc[it] += w
    ids = np.zeros(length, np.int64)
    ws = np.zeros(length, np.float32)
    for j, (it, weight) in enumerate(acc.items()):
        ids[j] = it
        ws[j] = weight
    return ids, ws


@dataclasses.dataclass
class CtxRow:
    user: int
    prev: int                  # item id + 1; NO_PREV if none
    hist: Tuple[np.ndarray, np.ndarray]
    age: int
    country: int
    gender: int
    device: int


def _row_from_state(ds, user: int, history: Sequence[int]) -> CtxRow:
    return CtxRow(
        user=user,
        prev=(history[-1] + 1) if history else NO_PREV,
        hist=_merge_bag([h + 1 for h in history], HIST_LEN),
        age=int(ds.age[user]), country=int(ds.country[user]),
        gender=int(ds.gender[user]), device=int(ds.device[user]),
    )


def build_ctx_design(ds, rows: List[CtxRow], features: str) -> Design:
    """features: subset string of 'A', 'P', 'U', 'H'."""
    specs = []
    n = len(rows)
    if "A" in features:
        specs += [
            dict(name="age", ids=np.array([r.age for r in rows]), vocab=ds.n_age),
            dict(name="country", ids=np.array([r.country for r in rows]),
                 vocab=ds.n_country),
            dict(name="gender", ids=np.array([r.gender for r in rows]),
                 vocab=ds.n_gender),
            dict(name="device", ids=np.array([r.device for r in rows]),
                 vocab=ds.n_device),
        ]
    if "P" in features:
        specs.append(dict(name="prev", ids=np.array([r.prev for r in rows]),
                          vocab=ds.n_items + 1))
    if "U" in features:
        specs.append(dict(name="user", ids=np.array([r.user for r in rows]),
                          vocab=ds.n_users))
    if "H" in features:
        ids = np.stack([r.hist[0] for r in rows])
        ws = np.stack([r.hist[1] for r in rows])
        specs.append(dict(name="hist", ids=ids, vocab=ds.n_items + 1, weights=ws))
    assert specs, f"empty feature set {features!r}"
    return make_design(specs, n)


def build_item_design(ds) -> Design:
    return make_design(
        [dict(name="item", ids=np.arange(ds.n_items), vocab=ds.n_items)],
        ds.n_items,
    )


# ---------------------------------------------------------------------------
# baselines
# ---------------------------------------------------------------------------
def popularity_scores(train_events: np.ndarray, n_items: int) -> np.ndarray:
    return np.bincount(train_events[:, 1], minlength=n_items).astype(np.float64)


def coview_matrix(train_events: np.ndarray, n_items: int) -> np.ndarray:
    """count[i, j] = #(j follows i) per user, fallback handled by caller."""
    count = np.zeros((n_items, n_items), np.float64)
    by_user: Dict[int, List[int]] = defaultdict(list)
    for u, i, t in train_events:
        by_user[u].append(i)
    for seq in by_user.values():
        for a, b in zip(seq[:-1], seq[1:]):
            count[a, b] += 1
    return count


# ---------------------------------------------------------------------------
# training wrappers
# ---------------------------------------------------------------------------
def train_icd_mf(ds, train_events, k=16, epochs=20, alpha0=0.5, l2=0.05, seed=0):
    pairs = np.unique(train_events[:, :2], axis=0)
    data = build_interactions(
        pairs[:, 0], pairs[:, 1], np.ones(len(pairs)),
        np.full(len(pairs), alpha0 + 4.0), ds.n_users, ds.n_items, alpha0=alpha0,
    )
    hp = mf.MFHyperParams(k=k, alpha0=alpha0, l2=l2)
    params = mf.init(jax.random.PRNGKey(seed), ds.n_users, ds.n_items, k)
    return mf.fit(params, data, hp, epochs), hp


def train_icd_fm(ds, ctx_design: Design, pairs: np.ndarray, n_ctx: int,
                 k=32, epochs=25, alpha0=0.5, l2=0.05, seed=0):
    """pairs: (nnz, 2) = (ctx_row_index, item)."""
    item_design = build_item_design(ds)
    data = build_interactions(
        pairs[:, 0], pairs[:, 1], np.ones(len(pairs)),
        np.full(len(pairs), alpha0 + 4.0), n_ctx, ds.n_items, alpha0=alpha0,
    )
    hp = fm.FMHyperParams(k=k, alpha0=alpha0, l2=l2, l2_lin=l2)
    params = fm.init(jax.random.PRNGKey(seed), ctx_design.p, item_design.p, k)
    params = fm.fit(params, ctx_design, item_design, data, hp, epochs)
    return params, hp, item_design


def fm_eval_scores(ds, params, hp, eval_design: Design, item_design: Design):
    pe = fm.phi_ext(params, eval_design, hp)
    se = fm.psi_ext(params, item_design, hp)
    return np.asarray(pe @ se.T)


# ---------------------------------------------------------------------------
# protocols
# ---------------------------------------------------------------------------
def split_cold_start(ds, frac=0.2, seed=0):
    rng = np.random.default_rng(seed)
    users = rng.permutation(ds.n_users)
    cold = set(users[: int(frac * ds.n_users)].tolist())
    train = ds.events[~np.isin(ds.events[:, 0], list(cold))]
    held: Dict[int, List[int]] = defaultdict(list)
    for u, i, t in ds.events:
        if u in cold:
            held[u].append(i)
    return train, held


def run_cold_start(ds=None, quick=False, seed=0) -> Dict[str, Dict[str, float]]:
    ds = ds or make_implicit_dataset(seed=seed)
    train, held = split_cold_start(ds, seed=seed)
    cold_users = sorted(held)
    truth = [sorted(set(held[u])) for u in cold_users]
    n_items = ds.n_items
    results = {}

    pop = popularity_scores(train, n_items)
    pop_scores = np.tile(pop, (len(cold_users), 1))
    results["popularity"] = _metrics(pop_scores, truth)

    # coview: cold users have no history → popularity fallback (paper: no
    # better than most-popular)
    results["coview"] = dict(results["popularity"])

    # iCD-MF: unseen users have no embedding → mean-embedding fallback
    params_mf, hp_mf = train_icd_mf(ds, train, epochs=6 if quick else 20, seed=seed)
    mean_w = np.asarray(params_mf.w).mean(axis=0, keepdims=True)
    mf_scores = np.tile(mean_w @ np.asarray(params_mf.h).T, (len(cold_users), 1))
    results["icd-mf"] = _metrics(mf_scores, truth)

    # iCD-FM A: attribute contexts (one row per TRAIN user)
    train_users = sorted(set(train[:, 0].tolist()))
    rows = [_row_from_state(ds, u, []) for u in train_users]
    design = build_ctx_design(ds, rows, "A")
    user_to_row = {u: r for r, u in enumerate(train_users)}
    pairs = np.array([[user_to_row[u], i] for u, i, t in train])
    pairs = np.unique(pairs, axis=0)
    params_fm, hp_fm, item_design = train_icd_fm(
        ds, design, pairs, len(train_users), epochs=5 if quick else 25, seed=seed)
    cold_rows = [_row_from_state(ds, u, []) for u in cold_users]
    eval_design = build_ctx_design(ds, cold_rows, "A")
    fm_scores = fm_eval_scores(ds, params_fm, hp_fm, eval_design, item_design)
    results["icd-fm A"] = _metrics(fm_scores, truth)
    return results


def split_offline(ds):
    """Hold out each user's last event."""
    last_idx = {}
    for idx, (u, i, t) in enumerate(ds.events):
        last_idx[u] = idx
    held_set = set(last_idx.values())
    train = ds.events[[i for i in range(len(ds.events)) if i not in held_set]]
    held = {int(ds.events[idx][0]): int(ds.events[idx][1])
            for idx in held_set}
    return train, held


def _event_rows_and_pairs(ds, events, features: str):
    """One context row per event, built from the user's state BEFORE it."""
    hist: Dict[int, List[int]] = defaultdict(list)
    rows, pairs = [], []
    for u, i, t in events:
        rows.append(_row_from_state(ds, u, hist[u]))
        pairs.append((len(rows) - 1, i))
        hist[u].append(i)
    return rows, np.asarray(pairs), hist


def run_offline(ds=None, quick=False, seed=0) -> Dict[str, Dict[str, float]]:
    ds = ds or make_implicit_dataset(seed=seed)
    train, held = split_offline(ds)
    users = sorted(held)
    truth = [[held[u]] for u in users]
    results = {}

    pop = popularity_scores(train, ds.n_items)
    results["popularity"] = _metrics(np.tile(pop, (len(users), 1)), truth)

    cov = coview_matrix(train, ds.n_items)
    state_hist: Dict[int, List[int]] = defaultdict(list)
    for u, i, t in train:
        state_hist[u].append(i)
    cov_scores = np.stack([
        cov[state_hist[u][-1]] if state_hist[u] else pop for u in users
    ])
    cov_scores = cov_scores + 1e-9 * pop  # popularity tiebreak
    results["coview"] = _metrics(cov_scores, truth)

    params_mf, _ = train_icd_mf(ds, train, epochs=6 if quick else 20, seed=seed)
    w, h = np.asarray(params_mf.w), np.asarray(params_mf.h)
    results["icd-mf"] = _metrics(w[users] @ h.T, truth)

    epochs = 5 if quick else 25
    for feats, label in (("A", "icd-fm A"), ("P", "icd-fm P"),
                         ("APU", "icd-fm A+P+U")):
        rows, pairs, _ = _event_rows_and_pairs(ds, train, feats)
        design = build_ctx_design(ds, rows, feats)
        params_fm, hp_fm, item_design = train_icd_fm(
            ds, design, pairs, len(rows), epochs=epochs, seed=seed)
        eval_rows = [_row_from_state(ds, u, state_hist[u]) for u in users]
        eval_design = build_ctx_design(ds, eval_rows, feats)
        scores = fm_eval_scores(ds, params_fm, hp_fm, eval_design, item_design)
        results[label] = _metrics(scores, truth)
    return results


def run_instant(ds=None, quick=False, seed=0, cutoff_frac=0.8):
    ds = ds or make_implicit_dataset(seed=seed)
    cutoff = int(cutoff_frac * len(ds.events))
    train, future = ds.events[:cutoff], ds.events[cutoff:]
    results = {}

    pop = popularity_scores(train, ds.n_items)

    # evaluate EVERY post-cutoff event; features update, params frozen
    hist: Dict[int, List[int]] = defaultdict(list)
    for u, i, t in train:
        hist[u].append(i)

    eval_states, truth = [], []
    run_hist = {u: list(v) for u, v in hist.items()}
    for u, i, t in future:
        eval_states.append((u, list(run_hist.get(u, []))))
        truth.append([int(i)])
        run_hist.setdefault(u, []).append(i)
    if quick:
        eval_states, truth = eval_states[:400], truth[:400]

    results["popularity"] = _metrics(
        np.tile(pop, (len(truth), 1)), truth)

    epochs = 5 if quick else 25
    for feats, label in (("A", "icd-fm A"), ("P", "icd-fm P"),
                         ("H", "icd-fm H"), ("APH", "icd-fm A+P+H")):
        rows, pairs, _ = _event_rows_and_pairs(ds, train, feats)
        design = build_ctx_design(ds, rows, feats)
        params_fm, hp_fm, item_design = train_icd_fm(
            ds, design, pairs, len(rows), epochs=epochs, seed=seed)
        eval_rows = [_row_from_state(ds, u, h) for u, h in eval_states]
        eval_design = build_ctx_design(ds, eval_rows, feats)
        scores = fm_eval_scores(ds, params_fm, hp_fm, eval_design, item_design)
        results[label] = _metrics(scores, truth)
    return results


def _metrics(scores: np.ndarray, truth) -> Dict[str, float]:
    r, n = recall_ndcg_multi(scores, truth, K_EVAL)
    return {"recall@100": r, "ndcg@100": n}


def relative_to_popularity(results: Dict[str, Dict[str, float]]):
    base = results["popularity"]
    return {
        name: {m: (v[m] / base[m] if base[m] > 0 else float("inf"))
               for m in v}
        for name, v in results.items()
    }
