from repro.kernels.cd_sweep.ops import cd_block_sweep  # noqa: F401
