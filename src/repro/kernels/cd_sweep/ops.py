"""Jit'd public wrappers for the fused multi-column CD block-sweep family.

``e`` is donated wherever it is consumed/replaced (the residual cache is
the largest carried tensor in the sweep), so an eager caller's buffer is
reused in place on backends that support donation. Inside an outer jit
(the ``*_padded.epoch`` paths) nested-jit donation is inert — there the
in-place update comes from the kernels' e→e_out ``input_output_aliases``
and from ``epoch`` donating ``e_pad`` at the top level.

Per-interaction confidence weights: every entry point takes an optional
``weights`` operand shaped like ``alpha``. The observed confidence enters
the sweep math purely multiplicatively (L'/2 = Σ ᾱ·e·ψ, L''/2 = Σ ᾱ·ψ²;
the implicit/Gram parts use the uniform ``alpha0`` only), so a weighted
sweep is EXACTLY a sweep over ``alpha·w`` — folded here, outside the
pallas call, rather than shipping a second VMEM operand to the kernel.
``weights=None`` is a trace-time branch: the jitted program is the
byte-identical unweighted one.
"""
from repro.kernels import kernel_jit
from repro.kernels.cd_sweep.kernel import (
    cd_block_sweep_gather_pallas,
    cd_block_sweep_pallas,
    cd_block_sweep_rowpatch_gather_pallas,
    cd_block_sweep_rowpatch_pallas,
    cd_resid_patch_gather_pallas,
    cd_resid_patch_pallas,
    cd_slab_reduce_gather_pallas,
    cd_slab_reduce_pallas,
)


def _fold_weights(alpha, weights):
    """alpha_eff = alpha·w (Lemma-1-rescaled confidence times per-interaction
    weight). ``weights is None`` short-circuits at trace time — no-op."""
    return alpha if weights is None else alpha * weights


@kernel_jit(static_argnames=("alpha0", "l2", "eta", "block_ctx"),
            donate_argnums=(2,))
def cd_block_sweep(psi_blk, alpha, e, w_blk, r1_blk, j_blk, *, alpha0, l2,
                   eta=1.0, block_ctx=None, weights=None, interpret=None):
    return cd_block_sweep_pallas(
        psi_blk, _fold_weights(alpha, weights), e, w_blk, r1_blk, j_blk,
        alpha0=alpha0, l2=l2, eta=eta, block_ctx=block_ctx,
        interpret=interpret,
    )


@kernel_jit(static_argnames=("alpha0", "l2", "eta", "block_ctx"),
            donate_argnums=(2,))
def cd_block_sweep_rowpatch(psi_blk, alpha, e, w_blk, r1_blk, p_blk, *,
                            alpha0, l2, eta=1.0, block_ctx=None,
                            weights=None, interpret=None):
    return cd_block_sweep_rowpatch_pallas(
        psi_blk, _fold_weights(alpha, weights), e, w_blk, r1_blk, p_blk,
        alpha0=alpha0, l2=l2, eta=eta, block_ctx=block_ctx,
        interpret=interpret,
    )


@kernel_jit(static_argnames=("block_ctx",))
def cd_slab_reduce(psi_blk, alpha, e, *, block_ctx=None, weights=None,
                   interpret=None):
    return cd_slab_reduce_pallas(
        psi_blk, _fold_weights(alpha, weights), e, block_ctx=block_ctx,
        interpret=interpret,
    )


@kernel_jit(static_argnames=("block_ctx",), donate_argnums=(1,))
def cd_resid_patch(psi_blk, e, dphi_blk, *, block_ctx=None, interpret=None):
    return cd_resid_patch_pallas(
        psi_blk, e, dphi_blk, block_ctx=block_ctx, interpret=interpret,
    )


# ---------------------------------------------------------------------------
# In-kernel Ψ gather variants: same math, but the kernel receives the full
# (n_src, m) ψ slab plus the (C, D_pad) id tile instead of a pre-gathered
# (C, m, D_pad) Ψ tile — the k_b× HBM-capacity intermediate never exists.
# ---------------------------------------------------------------------------
@kernel_jit(static_argnames=("alpha0", "l2", "eta", "block_ctx"),
            donate_argnums=(3,))
def cd_block_sweep_gather(psi_tab, ids, alpha, e, w_blk, r1_blk, j_blk, *,
                          alpha0, l2, eta=1.0, block_ctx=None, weights=None,
                          interpret=None):
    return cd_block_sweep_gather_pallas(
        psi_tab, ids, _fold_weights(alpha, weights), e, w_blk, r1_blk, j_blk,
        alpha0=alpha0, l2=l2, eta=eta, block_ctx=block_ctx,
        interpret=interpret,
    )


@kernel_jit(static_argnames=("alpha0", "l2", "eta", "block_ctx"),
            donate_argnums=(3,))
def cd_block_sweep_rowpatch_gather(psi_tab, ids, alpha, e, w_blk, r1_blk,
                                   p_blk, *, alpha0, l2, eta=1.0,
                                   block_ctx=None, weights=None,
                                   interpret=None):
    return cd_block_sweep_rowpatch_gather_pallas(
        psi_tab, ids, _fold_weights(alpha, weights), e, w_blk, r1_blk, p_blk,
        alpha0=alpha0, l2=l2, eta=eta, block_ctx=block_ctx,
        interpret=interpret,
    )


@kernel_jit(static_argnames=("block_ctx",))
def cd_slab_reduce_gather(psi_tab, ids, alpha, e, *, block_ctx=None,
                          weights=None, interpret=None):
    return cd_slab_reduce_gather_pallas(
        psi_tab, ids, _fold_weights(alpha, weights), e, block_ctx=block_ctx,
        interpret=interpret,
    )


@kernel_jit(static_argnames=("block_ctx",), donate_argnums=(2,))
def cd_resid_patch_gather(psi_tab, ids, e, dphi_blk, *, block_ctx=None,
                          interpret=None):
    return cd_resid_patch_gather_pallas(
        psi_tab, ids, e, dphi_blk, block_ctx=block_ctx, interpret=interpret,
    )
