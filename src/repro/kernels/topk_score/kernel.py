"""Pallas fused score+top-K retrieval kernel (the serving mirror of cd_sweep).

Every model in the zoo is k-separable (paper §4–5): a catalogue item scores
as ``⟨φ(context), ψ(item)⟩``, so retrieval and full-catalogue ranking
evaluation reduce to ONE dense sweep ``Φ_B · Ψᵀ`` followed by a per-row
top-K. The naive serving path materializes the whole ``(B, n_items)`` score
matrix in HBM and runs ``lax.top_k`` over it — at catalogue scale that is
2·B·n_items·4 B of pure score traffic on top of the irreducible ψ-table
read. This kernel fuses the two:

  grid = (B/block_b, n_items/block_items) — item blocks iterate fastest,
  so per φ tile the ψ table streams through VMEM exactly once:

    resident per (b) row-block:  φ tile (block_b, D), running top-K
                                 score/id blocks (block_b, K_pad)
    streamed per (b, i) step:    ψ tile (block_items, D)
                                 [optional] exclude tile (block_b,
                                 block_items) int8, or the per-row exclude
                                 ID tile (block_b, L_pad) int32
    compute per step:  S = φ·ψᵀ (MXU), mask exclusions/padding to −inf,
                       merge: top_k over [running K_pad | S] — scores and
                       ids together, in registers/VMEM

  The ``(B, n_items)`` score matrix NEVER exists: per step only the
  (block_b, block_items) tile is alive, and the merged state written back
  to HBM is the (block_b, K_pad) running top-K.

Shard support (serve/cluster.py): the kernel takes a traced ``(id_offset,
n_valid)`` scalar pair. Candidate ids are emitted as GLOBAL catalogue ids
(``id_offset + local``) and rows at local index ≥ ``n_valid`` are
inadmissible, so a row-range ψ shard padded to uniform size runs the very
same program — under ``shard_map`` the offset is ``axis_index·rows_per``
and the cross-shard K-way merge (``ops.topk_merge_shards``) combines the
per-shard (B, K) candidates without any id rebasing.

Exclusion comes in two forms:

  * ``exclude_mask`` (B, n_items) int8 — the legacy dense form; fine for
    query-batch-sized B at test scale, but one row IS the full catalogue.
  * ``exclude_ids`` (B, L) int32, −1-padded GLOBAL ids — the web-scale
    form: the kernel builds each (block_b, block_items) admissibility tile
    in-VMEM by comparing candidate ids against the per-row id list, so no
    (B, n_items) array exists on host or device.

Semantics (pinned by ``ref.topk_score_ref`` and the parity tests):

  * EXACT ``lax.top_k`` parity: scores and ids equal the dense
    ``lax.top_k(Φ·Ψᵀ, K)`` whenever at least K admissible candidates
    exist.
  * Tie policy (stable): equal scores rank in ascending item id, exactly
    like ``lax.top_k`` over an id-ordered dense row. This holds because
    ``lax.top_k`` is positionally stable, item blocks arrive in ascending
    id order, and the running state sits BEFORE the fresh tile in the
    merge concat — earlier (smaller-id) candidates always win ties.
  * Inadmissible slots: when a row has fewer than K admissible candidates
    (exclude mask covers the row, or K > n_items), the tail slots return
    id −1 with score −inf — excluded items never leak their ids, unlike a
    dense ``top_k`` over a −inf-masked matrix (which returns arbitrary
    real ids for the −inf tail). A genuinely −inf-scoring admissible item
    is indistinguishable from an excluded one by construction.

HBM traffic per query batch (fp32): dense path reads Ψ (N·D) + writes and
re-reads the score matrix (2·B·N); fused path reads Ψ (N·D) once and keeps
scores in VMEM — advantage ≈ 1 + 2B/D (≈5× at B=256, D=128; the analytic
model lives in ``benchmarks/serve_bench``).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import vmem


def _score_and_merge(block_items, k_pad, meta_ref, psi_ref, phi_ref, s_ref,
                     i_ref, excl_ref=None, exclid_ref=None, scale_ref=None):
    """One grid step: score the ψ tile and merge into the running top-K.

    ``meta_ref`` is the (1, 2) int32 ``[id_offset, n_valid]`` pair: ids are
    emitted as ``id_offset + local`` (global catalogue ids — shards pass
    their row-range start) and local ids ≥ ``n_valid`` are inadmissible
    (catalogue tail / shard padding).

    The ψ tile may arrive QUANTIZED (serving storage, ``serve/ann.py``):
    bf16 rows dequantize by the plain fp32 cast below; int8 rows carry a
    per-row fp32 scale tile (``scale_ref``, (block_items, 1)) and
    dequantize in-VMEM as ``q.astype(f32)·scale`` — either way the MXU
    accumulates in fp32 (``preferred_element_type``), so only the stored
    form narrows, never the score arithmetic."""
    step = pl.program_id(1)

    @pl.when(step == 0)
    def _init():
        s_ref[...] = jnp.full(s_ref.shape, -jnp.inf, jnp.float32)
        i_ref[...] = jnp.full(i_ref.shape, -1, jnp.int32)

    phi = phi_ref[...].astype(jnp.float32)   # (block_b, d_pad)
    psi = psi_ref[...].astype(jnp.float32)   # (block_items, d_pad)
    if scale_ref is not None:
        psi = psi * scale_ref[...]           # per-row dequant, broadcast (.,1)
    scores = jax.lax.dot_general(
        phi, psi, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                        # (block_b, block_items)
    offset = meta_ref[0, 0]
    n_valid = meta_ref[0, 1]
    local = step * block_items + jax.lax.broadcasted_iota(
        jnp.int32, scores.shape, 1
    )
    admissible = local < n_valid
    ids = offset + local                     # GLOBAL catalogue ids
    if excl_ref is not None:
        admissible &= excl_ref[...] == 0
    if exclid_ref is not None:
        # per-row exclude ID list (block_b, L_pad), −1 padding: a candidate
        # is excluded iff its GLOBAL id appears in its row's list — the
        # (block_b, block_items) admissibility tile is built right here,
        # so no (B, n_items) mask ever exists
        excl_ids = exclid_ref[...]           # (block_b, l_pad) int32
        hit = (ids[:, None, :] == excl_ids[:, :, None]).any(axis=1)
        admissible &= ~hit
    # inadmissible candidates keep −inf; they lose every tie against the
    # −inf/id−1 init state (which sits first in the concat), so their ids
    # never surface in the output
    scores = jnp.where(admissible, scores, -jnp.inf)

    # merge-in-registers: running state FIRST so positional stability of
    # top_k implements the ascending-id tie policy (blocks arrive id-sorted)
    cat_s = jnp.concatenate([s_ref[...], scores], axis=1)
    cat_i = jnp.concatenate([i_ref[...], ids], axis=1)
    new_s, sel = jax.lax.top_k(cat_s, k_pad)
    s_ref[...] = new_s
    i_ref[...] = jnp.take_along_axis(cat_i, sel, axis=1)


def _topk_kernel(block_items, k_pad, has_scale, excl_kind, *refs):
    """Generic ref unpacker for every (scale?, exclusion-form) variant.

    Ref order mirrors the in_specs the wrapper builds: meta, ψ,
    [per-row scale], φ, [exclude mask | exclude ids], then the two outputs.
    ``excl_kind``: 0 none, 1 dense mask, 2 id list."""
    it = iter(refs)
    meta_ref, psi_ref = next(it), next(it)
    scale_ref = next(it) if has_scale else None
    phi_ref = next(it)
    excl_ref = next(it) if excl_kind == 1 else None
    exclid_ref = next(it) if excl_kind == 2 else None
    s_ref, i_ref = next(it), next(it)
    _score_and_merge(block_items, k_pad, meta_ref, psi_ref, phi_ref, s_ref,
                     i_ref, excl_ref=excl_ref, exclid_ref=exclid_ref,
                     scale_ref=scale_ref)


_QUANT_DTYPES = ("int8", "bfloat16")


def topk_score_pallas(
    phi: jax.Array,       # (B, D) query φ rows
    psi: jax.Array,       # (n_rows, D) ψ table (or one row-range shard)
    k: int,
    exclude_mask: jax.Array | None = None,  # (B, n_rows) nonzero ⇒ never recommend
    *,
    exclude_ids: jax.Array | None = None,   # (B, L) GLOBAL ids, −1 padded
    psi_scale: jax.Array | None = None,     # (n_rows,) per-row dequant scale
    id_offset=0,                            # global id of ψ row 0 (traced ok)
    n_valid=None,                           # admissible local rows (traced ok)
    block_b: int = 128,
    block_items: int | None = None,
    interpret: bool = True,
):
    """Streaming fused top-K: returns ``(scores (B, k) f32, ids (B, k) i32)``.

    ``k`` may exceed the row count; inadmissible tail slots are (−inf, −1).
    ``block_items`` defaults to the shared VMEM-budget fit
    (:func:`repro.kernels.vmem.topk_block_items`). ``id_offset``/``n_valid``
    make a row-range shard emit global ids (see the module docstring); both
    may be traced scalars so one compiled program serves every shard.

    Quantized ψ storage: ``psi`` may be bf16 (cast-dequantized per tile) or
    int8 with a REQUIRED per-row ``psi_scale`` (the ``core.quant``
    per-row-scale form); either streams the narrow stored tile through VMEM
    and dequantizes in-kernel before the fp32-accumulating MXU dot, so
    score semantics (tie policy, admissibility) are unchanged — only the
    stored precision differs."""
    b, d = phi.shape
    n_rows, d2 = psi.shape
    assert d == d2, f"phi D={d} vs psi D={d2}"
    assert exclude_mask is None or exclude_ids is None, (
        "pass exclude_mask OR exclude_ids, not both"
    )
    if psi.dtype == jnp.int8 and psi_scale is None:
        raise ValueError("int8 psi needs psi_scale (per-row dequant scales)")
    if psi_scale is not None and psi_scale.shape[0] != n_rows:
        raise ValueError(
            f"psi_scale has {psi_scale.shape[0]} rows, psi has {n_rows}"
        )
    if n_valid is None:
        n_valid = n_rows

    lane = 128
    d_pad = -(-d // lane) * lane
    k_pad = -(-k // lane) * lane
    l_pad = 0
    if exclude_ids is not None:
        l_pad = -(-max(1, exclude_ids.shape[1]) // lane) * lane
    psi_bytes = psi.dtype.itemsize if str(psi.dtype) in _QUANT_DTYPES else 4
    block_b = min(block_b, -(-b // 8) * 8)
    if block_items is None:
        # The φ tile + running top-k_pad state are FIXED VMEM costs scaling
        # with block_b·(d_pad + k_pad); at large k_pad they alone can bust
        # the budget. block_b is ours to shrink — halve it until the tile
        # fits instead of silently overflowing VMEM.
        while True:
            try:
                block_items = vmem.topk_block_items(
                    block_b, d_pad, k_pad, n_items=n_rows, excl_l_pad=l_pad,
                    psi_bytes=psi_bytes, per_row_scale=psi_scale is not None,
                )
                break
            except vmem.VmemBudgetError:
                if block_b <= 8:
                    raise
                block_b = max(8, block_b // 2)
    b_pad = -(-b // block_b) * block_b
    n_pad = -(-n_rows // block_items) * block_items

    phi = jnp.pad(phi.astype(jnp.float32), ((0, b_pad - b), (0, d_pad - d)))
    if str(psi.dtype) not in _QUANT_DTYPES:
        psi = psi.astype(jnp.float32)       # quantized forms pad as stored
    psi = jnp.pad(psi, ((0, n_pad - n_rows), (0, d_pad - d)))
    meta = jnp.stack([
        jnp.asarray(id_offset, jnp.int32),
        jnp.minimum(jnp.asarray(n_valid, jnp.int32), n_rows),
    ]).reshape(1, 2)

    grid = (b_pad // block_b, n_pad // block_items)
    out_specs = [
        pl.BlockSpec((block_b, k_pad), lambda bb, ii: (bb, 0)),
        pl.BlockSpec((block_b, k_pad), lambda bb, ii: (bb, 0)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((b_pad, k_pad), jnp.float32),
        jax.ShapeDtypeStruct((b_pad, k_pad), jnp.int32),
    ]
    in_specs = [
        pl.BlockSpec((1, 2), lambda bb, ii: (0, 0)),                 # meta
        pl.BlockSpec((block_items, d_pad), lambda bb, ii: (ii, 0)),  # ψ
    ]
    args = [meta, psi]
    if psi_scale is not None:
        scale = jnp.pad(
            psi_scale.astype(jnp.float32).reshape(-1, 1),
            ((0, n_pad - n_rows), (0, 0)), constant_values=1.0,
        )
        in_specs.append(
            pl.BlockSpec((block_items, 1), lambda bb, ii: (ii, 0))
        )
        args.append(scale)
    in_specs.append(pl.BlockSpec((block_b, d_pad), lambda bb, ii: (bb, 0)))
    args.append(phi)

    excl_kind = 0
    if exclude_mask is not None:
        excl_kind = 1
        in_specs.append(
            pl.BlockSpec((block_b, block_items), lambda bb, ii: (bb, ii))
        )
        args.append(jnp.pad(
            exclude_mask.astype(jnp.int8),
            ((0, b_pad - b), (0, n_pad - n_rows)),
        ))
    elif exclude_ids is not None:
        excl_kind = 2
        in_specs.append(pl.BlockSpec((block_b, l_pad), lambda bb, ii: (bb, 0)))
        args.append(jnp.pad(
            exclude_ids.astype(jnp.int32),
            ((0, b_pad - b), (0, l_pad - exclude_ids.shape[1])),
            constant_values=-1,
        ))

    scores, ids = pl.pallas_call(
        partial(_topk_kernel, block_items, k_pad, psi_scale is not None,
                excl_kind),
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(*args)
    return scores[:b, :k], ids[:b, :k]
