"""Unit coverage for the shared VMEM-budget blocking policy
(``repro.kernels.vmem``): budget respected, ``n_rows`` cap, ``multiple``
rounding, and the fixed-bytes-overflow behavior (raise, don't silently
return a tile that overflows VMEM)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import vmem


def test_fit_block_rows_budget_respected():
    per_row = 1000
    rows = vmem.fit_block_rows(per_row, budget=100_000)
    assert rows * per_row <= 100_000
    assert rows % 8 == 0 and rows >= 8


def test_fit_block_rows_fixed_bytes_reduce_rows():
    per_row = 1000
    free = vmem.fit_block_rows(per_row, budget=100_000)
    with_fixed = vmem.fit_block_rows(per_row, fixed_bytes=50_000, budget=100_000)
    assert with_fixed < free
    assert 50_000 + with_fixed * per_row <= 100_000


def test_fit_block_rows_n_rows_cap():
    # a tiny problem must not be padded up to a huge tile...
    assert vmem.fit_block_rows(4, n_rows=10) == 16
    # ...and the cap rounds UP to the multiple so one grid step covers it
    assert vmem.fit_block_rows(4, n_rows=100, multiple=128, lo=128) == 128


def test_fit_block_rows_multiple_rounding():
    rows = vmem.fit_block_rows(1000, budget=100_000, multiple=16)
    assert rows % 16 == 0
    # 100 rows fit; floor to the multiple, not up
    assert rows == 96


def test_fit_block_rows_hi_clamp():
    assert vmem.fit_block_rows(1, budget=1 << 30, hi=2048) == 2048


def test_fit_block_rows_fixed_overflow_raises():
    """The old behavior silently returned the ``lo`` floor even when
    ``fixed_bytes`` alone exceeded the budget — reachable via
    ``topk_block_items`` at large block_b·k_pad and via the gather kernels'
    ψ slab. It must raise a clear error instead."""
    with pytest.raises(vmem.VmemBudgetError):
        vmem.fit_block_rows(1000, fixed_bytes=200_000, budget=100_000)
    # per-row cost alone busting the budget at lo rows also raises
    with pytest.raises(vmem.VmemBudgetError):
        vmem.fit_block_rows(100_000, budget=100_000, lo=8)


def test_cd_sweep_block_ctx_budget():
    d_pad, k_b = 1024, 8
    rows = vmem.cd_sweep_block_ctx(d_pad, k_b)
    per_row = 4 * ((k_b + 3) * d_pad + k_b * k_b + 4 * k_b)
    assert rows * per_row <= vmem.VMEM_BUDGET_BYTES
    assert rows >= 8


def test_cd_sweep_block_ctx_floors_at_pathological_d_pad():
    """The pre-gathered fit is the dispatch of last resort: a degree-skewed
    d_pad whose minimal tile busts the soft budget floors at lo rows (the
    pre-PR-4 behavior) instead of raising — and the dispatch resolver
    therefore never escalates."""
    rows = vmem.cd_sweep_block_ctx(d_pad=40_000, k_b=8)
    assert rows == 8
    use_gather, block_ctx = vmem.resolve_cd_sweep_dispatch(
        40_000, 8, n_src=50_000_000, n_rows=100
    )
    assert not use_gather and block_ctx == 8


def test_cd_sweep_gather_block_ctx_slab_is_fixed():
    """The gather variant charges the ψ slab as FIXED bytes: growing n_src
    shrinks the row tile only past the point where the slab eats into the
    budget, and a slab alone larger than the budget raises."""
    d_pad, k_b = 1024, 8
    small = vmem.cd_sweep_gather_block_ctx(d_pad, k_b, n_src=1_000)
    big = vmem.cd_sweep_gather_block_ctx(d_pad, k_b, n_src=100_000)
    assert small >= big
    with pytest.raises(vmem.VmemBudgetError):
        # 10M-row slab × 8 cols × 4 B ≈ 320 MB ≫ the 8 MiB budget
        vmem.cd_sweep_gather_block_ctx(d_pad, k_b, n_src=10_000_000)


def test_resolve_cd_sweep_dispatch_fallback():
    d_pad, k_b = 1024, 8
    use_gather, _ = vmem.resolve_cd_sweep_dispatch(d_pad, k_b, 1_000)
    assert use_gather
    # slab too big → pre-gathered fallback instead of an exception
    use_gather, block_ctx = vmem.resolve_cd_sweep_dispatch(
        d_pad, k_b, 10_000_000
    )
    assert not use_gather
    assert block_ctx == vmem.cd_sweep_block_ctx(d_pad, k_b)
    # explicit pregather pin skips the gather fit entirely
    use_gather, _ = vmem.resolve_cd_sweep_dispatch(
        d_pad, k_b, 1_000, prefer_gather=False
    )
    assert not use_gather
    # compiled backends must not default onto the interpret-only gather
    # path (its Mosaic lowering is a follow-up)
    use_gather, _ = vmem.resolve_cd_sweep_dispatch(
        d_pad, k_b, 1_000, interpret=False
    )
    assert not use_gather


def test_topk_block_items_overflow_raises():
    """Large block_b·k_pad: the fixed φ/top-k state alone busts the budget."""
    with pytest.raises(vmem.VmemBudgetError):
        vmem.topk_block_items(block_b=2048, d_pad=128, k_pad=65536)


def test_topk_block_items_exclude_id_tile_charged():
    """The exclude-ID variant's resident (block_b, L_pad) tile and per-row
    membership compare must shrink the ψ tile, not ride for free."""
    free = vmem.topk_block_items(block_b=128, d_pad=128, k_pad=128)
    with_ids = vmem.topk_block_items(block_b=128, d_pad=128, k_pad=128,
                                     excl_l_pad=256)
    assert with_ids < free
    with pytest.raises(vmem.VmemBudgetError):
        # a pathologically wide exclude list busts even the minimal tile
        # (the kernel wrapper's block_b-halving loop is the way out)
        vmem.topk_block_items(block_b=128, d_pad=128, k_pad=128,
                              excl_l_pad=2048)


def test_cluster_block_items_merge_scratch_is_fixed_cost():
    """The cross-shard merge scratch (S·K candidate score+id rows) is a
    FIXED cost growing with the shard count: more shards ⇒ same-or-smaller
    per-shard ψ tile, and a scratch alone over budget raises (the cluster
    PROPAGATES instead of shrinking below one ψ block)."""
    kw = dict(d_pad=128, k_pad=128, block_b=128)
    single = vmem.topk_block_items(**kw)
    s2 = vmem.cluster_block_items(n_shards=2, **kw)
    s16 = vmem.cluster_block_items(n_shards=16, **kw)
    assert s2 <= single and s16 <= s2
    with pytest.raises(vmem.VmemBudgetError):
        # 1024 shards × k_pad 8192 of merge scratch ≫ the budget
        vmem.cluster_block_items(block_b=128, d_pad=128, k_pad=8192,
                                 n_shards=1024)


def test_topk_score_shrinks_block_b_on_overflow(monkeypatch):
    """The kernel wrapper owns the shrinkable fixed dimension: under a tiny
    budget it must halve block_b until the tile fits and still produce
    oracle-exact top-k (not silently overflow VMEM)."""
    from repro.kernels.topk_score.kernel import topk_score_pallas
    from repro.kernels.topk_score.ref import topk_score_ref

    # small enough that block_b=128 would demand > budget fixed bytes
    monkeypatch.setattr(vmem, "VMEM_BUDGET_BYTES", 300_000)
    with pytest.raises(vmem.VmemBudgetError):
        vmem.topk_block_items(block_b=128, d_pad=128, k_pad=128)

    # 200 query rows keep the initial block_b at 128, forcing the shrink
    # loop (128 → 64 → 32 fits under the shrunken budget)
    rng = np.random.default_rng(0)
    phi = jnp.asarray(rng.normal(size=(200, 16)), jnp.float32)
    psi = jnp.asarray(rng.normal(size=(300, 16)), jnp.float32)
    scores, ids = topk_score_pallas(phi, psi, k=10, interpret=True)
    exp_scores, exp_ids = topk_score_ref(phi, psi, k=10)
    np.testing.assert_array_equal(np.asarray(ids), np.asarray(exp_ids))
    np.testing.assert_allclose(np.asarray(scores), np.asarray(exp_scores),
                               rtol=1e-5, atol=1e-6)


def test_gather_kernel_uses_budgeted_tile():
    """End-to-end: the gather sweep kernel resolves its own block_ctx from
    the budget and still matches the pre-gathered kernel."""
    from repro.kernels.cd_sweep.kernel import (
        cd_block_sweep_gather_pallas,
        cd_block_sweep_pallas,
    )
    from repro.kernels.cd_sweep.ref import gather_psi_blk

    rng = np.random.default_rng(3)
    c, d_pad, k_b, n_src = 50, 128, 4, 23
    tab = jnp.asarray(rng.normal(size=(n_src, k_b)), jnp.float32)
    ids = jnp.asarray(rng.integers(0, n_src, (c, d_pad)), jnp.int32)
    alpha = jnp.asarray(rng.random((c, d_pad)), jnp.float32)
    e = jnp.asarray(rng.normal(size=(c, d_pad)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(c, k_b)), jnp.float32)
    r1 = jnp.asarray(rng.normal(size=(c, k_b)), jnp.float32)
    jb = rng.normal(size=(k_b, k_b))
    jb = jnp.asarray(jb @ jb.T + k_b * np.eye(k_b), jnp.float32)
    args = dict(alpha0=0.4, l2=0.05, eta=1.0)
    w1, e1 = cd_block_sweep_pallas(
        gather_psi_blk(tab, ids), alpha, e, w, r1, jb, interpret=True, **args
    )
    w2, e2 = cd_block_sweep_gather_pallas(
        tab, ids, alpha, e, w, r1, jb, interpret=True, **args
    )
    np.testing.assert_allclose(np.asarray(w1), np.asarray(w2), rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(np.asarray(e1), np.asarray(e2), rtol=1e-6, atol=1e-7)


def test_jit_shapes_stable_under_budget():
    """block_ctx resolution happens at trace time on static shapes — the
    same call twice must hit the jit cache (no per-call recomputation
    changing shapes)."""
    from repro.kernels.cd_sweep.ops import cd_block_sweep_gather

    rng = np.random.default_rng(4)
    c, d_pad, k_b, n_src = 20, 128, 2, 11
    tab = jnp.asarray(rng.normal(size=(n_src, k_b)), jnp.float32)
    ids = jnp.asarray(rng.integers(0, n_src, (c, d_pad)), jnp.int32)
    alpha = jnp.asarray(rng.random((c, d_pad)), jnp.float32)
    e = jnp.asarray(rng.normal(size=(c, d_pad)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(c, k_b)), jnp.float32)
    r1 = jnp.asarray(rng.normal(size=(c, k_b)), jnp.float32)
    jb = jnp.eye(k_b, dtype=jnp.float32)
    w1, e1 = cd_block_sweep_gather(tab, ids, alpha, e, w, r1, jb,
                                   alpha0=0.4, l2=0.05)
    w2, e2 = cd_block_sweep_gather(tab, ids, alpha, jnp.asarray(e1), w, r1,
                                   jb, alpha0=0.4, l2=0.05)
    assert w2.shape == w1.shape and e2.shape == e1.shape
    assert bool(jnp.isfinite(w2).all())


def test_resolve_psi_dispatch_validates():
    """A typo'd psi_dispatch must raise, not silently select the
    k_b×-peak-HBM pre-gathered path."""
    from repro.core import sweeps

    assert sweeps.resolve_psi_dispatch("gather") is True
    assert sweeps.resolve_psi_dispatch("pregather") is False
    with pytest.raises(ValueError, match="psi_dispatch"):
        sweeps.resolve_psi_dispatch("Gather")
    with pytest.raises(ValueError, match="psi_dispatch"):
        sweeps.resolve_psi_dispatch("in-kernel")


def test_budget_constant_sane():
    assert vmem.VMEM_BUDGET_BYTES <= vmem.VMEM_BYTES
    assert vmem.VMEM_BUDGET_BYTES >= 1 << 20
