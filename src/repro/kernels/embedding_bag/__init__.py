from repro.kernels.embedding_bag.ops import embedding_bag_dense  # noqa: F401
