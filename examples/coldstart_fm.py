"""Cold-start with iCD-FM (paper §6.2.1): attribute features rescue users
the model has never seen.

    PYTHONPATH=src:. python examples/coldstart_fm.py
"""
import json

from benchmarks.experiments import paper_dataset, relative_to_popularity, run_cold_start


def main():
    ds = paper_dataset(quick=True)
    results = run_cold_start(ds, quick=True)
    rel = relative_to_popularity(results)
    print(json.dumps(rel, indent=1))
    assert rel["icd-fm A"]["ndcg@100"] > 1.5, "FM-A should be ≫ popularity"
    assert rel["icd-mf"]["ndcg@100"] < 1.2, "MF cannot help cold users"
    print("\ncold-start: attribute FM beats popularity ~2x, MF does not — "
          "matches Figure 7")


if __name__ == "__main__":
    main()
