"""SGD with (Nesterov) momentum."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.optim.base import OptimizerDef


def sgd(lr, momentum: float = 0.0, nesterov: bool = False) -> OptimizerDef:
    lr_fn = lr if callable(lr) else (lambda step: lr)

    def init(params):
        mom = (
            jax.tree_util.tree_map(lambda p: jnp.zeros_like(p, jnp.float32), params)
            if momentum else None
        )
        return {"step": jnp.zeros((), jnp.int32), "mom": mom}

    def update(grads, state, params):
        step = state["step"] + 1
        lr_t = lr_fn(step)
        if momentum:
            mom = jax.tree_util.tree_map(
                lambda m, g: momentum * m + g.astype(jnp.float32),
                state["mom"], grads,
            )
            if nesterov:
                upd = jax.tree_util.tree_map(
                    lambda m, g: -(lr_t * (momentum * m + g.astype(jnp.float32))),
                    mom, grads,
                )
            else:
                upd = jax.tree_util.tree_map(lambda m: -lr_t * m, mom)
            return upd, {"step": step, "mom": mom}
        upd = jax.tree_util.tree_map(lambda g: -lr_t * g.astype(jnp.float32), grads)
        return upd, {"step": step, "mom": None}

    return OptimizerDef(init, update)
