"""Online retrieval serving: single-device engine, sharded cluster,
fault-tolerant replicated mesh, request micro-batching, live ψ publish
from training, and the IVF approximate tier with quantized ψ storage
(see serve/README.md for the operations guide)."""
from repro.serve.ann import (  # noqa: F401
    AnnConfig,
    PsiIndex,
    build_shard_indexes,
    fold_delta_indexes,
    ivf_cluster_topk,
    kmeans,
)
from repro.serve.batcher import MicroBatcher  # noqa: F401
from repro.serve.cluster import (  # noqa: F401
    PsiShardSet,
    ShardedRetrievalCluster,
    TopKResult,
    cluster_topk,
    shard_map_topk,
    shard_psi,
)
from repro.serve.engine import (  # noqa: F401
    RetrievalEngine,
    exclude_ids_from_lists,
    exclude_mask_from_lists,
)
from repro.serve.mesh import (  # noqa: F401
    FaultInjector,
    FaultTolerantRetrievalMesh,
    ReplicaSet,
    RetryPolicy,
    ShardHealthMonitor,
)
from repro.serve.publish import (  # noqa: F401
    PsiPublisher,
    StagedRollout,
    VersionedTable,
    apply_delta,
    dense_table,
)
from repro.kernels.topk_score.ref import retrieval_topk  # noqa: F401
from repro.serve.engine import bulk_score, mf_retrieval_score_fn  # noqa: F401
