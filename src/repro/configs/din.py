"""DIN [arXiv:1706.06978] — target attention over 100-item history."""
import dataclasses

from repro.configs.base import RECSYS_SHAPES, RecsysConfig

CONFIG = RecsysConfig(
    name="din",
    kind="din",
    embed_dim=18,
    seq_len=100,
    attn_mlp=(80, 40),
    mlp=(200, 80),
    item_vocab=10_000_000,
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG, embed_dim=6, seq_len=12, attn_mlp=(16, 8), mlp=(24, 12),
    item_vocab=200,
)

SHAPES = RECSYS_SHAPES
