"""Shared NN building blocks (no flax — explicit param pytrees)."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    xf = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (xf * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array, eps: float = 1e-5):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    xf = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (xf * scale + bias).astype(dt)


def rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """Rotary embedding. x: (..., seq, n_heads, head_dim), positions: (..., seq)."""
    head_dim = x.shape[-1]
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., seq, half)
    cos = jnp.cos(angles)[..., None, :]  # (..., seq, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def dense_init(key, shape, fan_in: Optional[int] = None, dtype=jnp.float32):
    fan_in = fan_in if fan_in is not None else shape[0]
    return (1.0 / math.sqrt(fan_in)) * jax.random.normal(key, shape, dtype)


def mlp_init(key, dims, dtype=jnp.float32, bias: bool = True):
    """[(w, b), ...] for dims = (in, h1, ..., out)."""
    keys = jax.random.split(key, len(dims) - 1)
    layers = []
    for k, din, dout in zip(keys, dims[:-1], dims[1:]):
        w = dense_init(k, (din, dout), dtype=dtype)
        layers.append(
            {"w": w, "b": jnp.zeros((dout,), dtype)} if bias else {"w": w}
        )
    return layers


def mlp_apply(layers, x, act=jax.nn.relu, final_act=None):
    for i, layer in enumerate(layers):
        x = x @ layer["w"].astype(x.dtype)
        if "b" in layer:
            x = x + layer["b"].astype(x.dtype)
        if i < len(layers) - 1:
            x = act(x)
        elif final_act is not None:
            x = final_act(x)
    return x


def softcap(x: jax.Array, cap: Optional[float]) -> jax.Array:
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


def count_params(tree) -> int:
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(tree))
