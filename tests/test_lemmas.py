"""Direct verification of the paper's three lemmas + property-based tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need hypothesis; CI installs it
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.gram import gram, weighted_gram
from repro.core.implicit import (
    implicit_regularizer_gram,
    implicit_regularizer_naive,
    rescale_observed,
)


# --------------------------------------------------------------------------
# Lemma 1: L(Θ|S_impl) == L(Θ|S̄) + α₀ R(Θ) + const
# --------------------------------------------------------------------------
def _loss_on(scores, y, alpha):
    return np.sum(alpha * (scores - y) ** 2)


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    n_ctx=st.integers(2, 8),
    n_items=st.integers(2, 8),
    alpha0=st.floats(0.05, 2.0),
)
def test_lemma1_objective_equivalence(seed, n_ctx, n_items, alpha0):
    """The difference L_impl − (L_rescaled + α₀R) must be the SAME constant
    for arbitrary parameter settings (the proof's additive const)."""
    rng = np.random.default_rng(seed)
    nnz = rng.integers(1, n_ctx * n_items + 1)
    cells = rng.choice(n_ctx * n_items, size=nnz, replace=False)
    ctx, item = cells // n_items, cells % n_items
    y = rng.normal(size=nnz)
    alpha = alpha0 + 0.5 + rng.random(nnz)

    y_bar, a_bar = rescale_observed(jnp.asarray(y), jnp.asarray(alpha), alpha0)

    consts = []
    for pseed in (1, 2, 3):
        prng = np.random.default_rng(pseed)
        scores = prng.normal(size=(n_ctx, n_items))
        # full implicit loss over S_impl
        y_dense = np.zeros((n_ctx, n_items))
        a_dense = np.full((n_ctx, n_items), alpha0)
        y_dense[ctx, item] = y
        a_dense[ctx, item] = alpha
        l_impl = _loss_on(scores, y_dense, a_dense)
        # Lemma-1 form
        l_resc = _loss_on(scores[ctx, item], np.asarray(y_bar), np.asarray(a_bar))
        r = np.sum(scores**2)
        consts.append(l_impl - (l_resc + alpha0 * r))
    np.testing.assert_allclose(consts[0], consts[1], rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(consts[0], consts[2], rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------------------
# Lemma 2: R(Θ) = Σ_{f,f'} J_C(f,f')·J_I(f,f')
# --------------------------------------------------------------------------
@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    n_ctx=st.integers(1, 30),
    n_items=st.integers(1, 30),
    k=st.integers(1, 8),
)
def test_lemma2_gram_decomposition(seed, n_ctx, n_items, k):
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    phi = jax.random.normal(k1, (n_ctx, k))
    psi = jax.random.normal(k2, (n_items, k))
    np.testing.assert_allclose(
        implicit_regularizer_gram(phi, psi),
        implicit_regularizer_naive(phi, psi),
        rtol=2e-5,
    )


# --------------------------------------------------------------------------
# Lemma 3: R'(θ) via Gram == autodiff of the naive regularizer (MF case)
# --------------------------------------------------------------------------
def test_lemma3_gradients_match_autodiff():
    key = jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)
    w = jax.random.normal(k1, (7, 4))
    h = jax.random.normal(k2, (5, 4))

    grad_naive = jax.grad(lambda w_: implicit_regularizer_naive(w_, h))(w)
    # eq. (18): R'(w_{c,f}) = 2 Σ_f' J_I(f',f) w_{c,f'} = 2 · W @ J_I
    grad_lemma = 2.0 * w @ gram(h)
    np.testing.assert_allclose(grad_naive, grad_lemma, rtol=1e-5, atol=1e-6)

    # second derivative (eq. 19): R'' = 2·J_I(f,f) — via autodiff diagonal
    def r_coord(val, c, f):
        return implicit_regularizer_naive(w.at[c, f].set(val), h)

    for c, f in [(0, 0), (3, 2), (6, 3)]:
        d2 = jax.grad(jax.grad(r_coord))(w[c, f], c, f)
        np.testing.assert_allclose(d2, 2.0 * gram(h)[f, f], rtol=1e-5)


# --------------------------------------------------------------------------
# Gram op properties
# --------------------------------------------------------------------------
@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), rows=st.integers(1, 50), k=st.integers(1, 10))
def test_gram_matches_numpy(seed, rows, k):
    m = jax.random.normal(jax.random.PRNGKey(seed), (rows, k))
    np.testing.assert_allclose(gram(m), np.asarray(m).T @ np.asarray(m), rtol=2e-5, atol=1e-5)


def test_weighted_gram():
    m = jax.random.normal(jax.random.PRNGKey(1), (20, 5))
    w = jax.random.uniform(jax.random.PRNGKey(2), (20,))
    expect = np.asarray(m).T @ (np.asarray(w)[:, None] * np.asarray(m))
    np.testing.assert_allclose(weighted_gram(m, w), expect, rtol=2e-5, atol=1e-5)


# --------------------------------------------------------------------------
# Rescaling properties (eq. 8)
# --------------------------------------------------------------------------
@settings(max_examples=50, deadline=None)
@given(
    y=st.floats(-5, 5),
    alpha=st.floats(0.1, 10.0),
    alpha0=st.floats(0.01, 5.0),
)
def test_rescale_collapses_pair(y, alpha, alpha0):
    """ᾱ(ŷ−ȳ)² must differ from α(ŷ−y)² − α₀ŷ² by a ŷ-independent const."""
    if alpha <= alpha0 + 1e-3:
        return
    y_bar, a_bar = rescale_observed(jnp.float32(y), jnp.float32(alpha), alpha0)
    consts = []
    for s in (-2.0, 0.3, 1.7):
        lhs = float(a_bar) * (s - float(y_bar)) ** 2
        rhs = alpha * (s - y) ** 2 - alpha0 * s**2
        consts.append(lhs - rhs)
    np.testing.assert_allclose(consts[0], consts[1], rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(consts[0], consts[2], rtol=1e-3, atol=1e-3)
