import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input-shape)
cell on the production meshes and derive the roofline terms.

MUST be run as a module entry point (``python -m repro.launch.dryrun``) so
the two lines above execute before ANY other jax import in the process —
jax locks the device count at first init.

Usage:
  python -m repro.launch.dryrun --mesh both --out results/dryrun
  python -m repro.launch.dryrun --arch icd-mf --shape epoch_youtube --mesh single
  python -m repro.launch.dryrun --list
"""
import argparse
import json
import time
import traceback

import jax

from repro.launch import hlo_analysis
from repro.launch.cells import all_cell_ids, build_cell
from repro.launch.mesh import make_production_mesh, n_chips
from repro.launch.sharding import named

MODEL_FLOPS_NOTE = (
    "model_flops = 6·N·D (dense train) / 6·N_active·D (MoE) — computed by "
    "benchmarks/roofline_bench.py and joined into EXPERIMENTS.md"
)


def run_cell(arch: str, shape: str, multi_pod: bool, save_hlo: str = ""):
    mesh = make_production_mesh(multi_pod=multi_pod)
    cell = build_cell(arch, shape, mesh)
    result = {
        "arch": arch, "shape": shape,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": n_chips(mesh), "kind": cell.kind, "notes": cell.notes,
    }
    if cell.skip:
        result["status"] = "skipped"
        result["skip_reason"] = cell.skip
        return result

    t0 = time.time()
    with mesh:
        in_sh = tuple(named(mesh, s) for s in cell.in_specs)
        out_sh = named(mesh, cell.out_specs)
        lowered = jax.jit(
            cell.step_fn, in_shardings=in_sh, out_shardings=out_sh
        ).lower(*cell.abstract_args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    roof = hlo_analysis.roofline_from_compiled(compiled)
    result.update(
        status="ok",
        lower_s=round(t_lower, 2),
        compile_s=round(t_compile, 2),
        memory=hlo_analysis.memory_stats(compiled),
        roofline_raw=roof.to_dict(),
    )
    # (the scanned-LM probe calibration hook left with the seed-template LM
    # configs in PR 4 — iCD cells report the raw HLO roofline directly)
    result["roofline"] = roof.to_dict()
    if save_hlo:
        with open(save_hlo, "w") as f:
            f.write(compiled.as_text())
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--save-hlo", default="")
    args = ap.parse_args()

    cells = all_cell_ids()
    if args.arch:
        cells = [(a, s) for a, s in cells if a == args.arch]
    if args.shape:
        cells = [(a, s) for a, s in cells if s == args.shape]
    if args.list:
        for a, s in cells:
            print(f"{a} × {s}")
        return

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    os.makedirs(args.out, exist_ok=True)
    n_fail = 0
    for arch, shape in cells:
        for multi_pod in meshes:
            tag = f"{arch}__{shape}__{'mp' if multi_pod else 'sp'}"
            path = os.path.join(args.out, tag + ".json")
            if os.path.exists(path):
                prev = json.load(open(path))
                if prev.get("status") in ("ok", "skipped"):
                    print(f"[skip-cached] {tag}")
                    continue
            print(f"[dryrun] {tag} ...", flush=True)
            try:
                res = run_cell(arch, shape, multi_pod, save_hlo=args.save_hlo and
                               os.path.join(args.out, tag + ".hlo"))
            except Exception as e:  # noqa: BLE001 — record and continue
                res = {"arch": arch, "shape": shape,
                       "mesh": "2x16x16" if multi_pod else "16x16",
                       "status": "error", "error": repr(e),
                       "traceback": traceback.format_exc()[-3000:]}
                n_fail += 1
            with open(path, "w") as f:
                json.dump(res, f, indent=1)
            status = res["status"]
            extra = ""
            if status == "ok":
                r = res["roofline"]
                extra = (f" dominant={r['dominant']}"
                         f" compute={r['compute_s']:.3e}s"
                         f" memory={r['memory_s']:.3e}s"
                         f" coll={r['collective_s']:.3e}s"
                         f" compile={res['compile_s']:.0f}s")
            print(f"[dryrun] {tag}: {status}{extra}", flush=True)
    print(f"[dryrun] done, {n_fail} failures")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
