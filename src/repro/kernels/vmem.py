"""Shared VMEM-budget blocking policy for the Pallas kernel wrappers.

Every kernel in this package streams `(rows, lanes)` tiles through VMEM
(~16 MiB/core); the row-tile size is the knob that trades grid steps
against VMEM pressure. Before this module each call site carried its own
constant (``mf_padded._SWEEP_BLOCK_CTX = 128``, ``block_ctx=128`` defaults
in the cd_sweep ops, ...). Now there is ONE declared budget and one
fitting rule; the per-kernel helpers below encode each kernel's bytes/row
so wrappers can resolve ``block_ctx``/``block_items`` from the actual tile
shapes at trace time (shapes are static under jit, so the choice bakes
into the compiled program).

The ``k_b`` (columns per fused cd_sweep dispatch) side of the trade lives
in ``core.sweeps.resolve_block_k``: its auto policy ``min(k, 8)`` is the
bandwidth knee of the analytic model in ``benchmarks/roofline_bench`` —
beyond k_b≈8 the amortized α/e traffic saving flattens while the Ψ tile's
VMEM (and HBM capacity) cost keeps growing linearly, so the budget here
only has to fit the row tile given that k_b.
"""
from __future__ import annotations

VMEM_BYTES = 16 * 1024 * 1024
# Working budget: half the core's VMEM, leaving headroom for the pipeline's
# double buffering and the compiler's own temporaries.
VMEM_BUDGET_BYTES = VMEM_BYTES // 2


def fit_block_rows(
    per_row_bytes: int,
    *,
    fixed_bytes: int = 0,
    n_rows: int | None = None,
    budget: int = VMEM_BUDGET_BYTES,
    multiple: int = 8,
    lo: int = 8,
    hi: int = 2048,
) -> int:
    """Largest row-tile (multiple of ``multiple``, in [lo, hi]) whose VMEM
    footprint ``fixed_bytes + rows·per_row_bytes`` fits the budget.

    ``n_rows`` (when known) caps the tile at the padded problem size so a
    small problem is one grid step instead of being padded up to a huge
    tile."""
    rows = max(lo, (budget - fixed_bytes) // max(1, per_row_bytes))
    rows = min(rows, hi)
    if n_rows is not None:
        rows = min(rows, -(-n_rows // multiple) * multiple)
    return max(lo, (rows // multiple) * multiple)


def cd_sweep_block_ctx(d_pad: int, k_b: int, *, n_rows: int | None = None) -> int:
    """Row tile for the ``cd_sweep`` kernel family.

    Per row the block kernels hold the Ψ tile (k_b, d_pad), α and e
    (d_pad each, plus the aliased e output) and the small (k_b,) slabs in
    VMEM — ≈ (k_b + 3)·d_pad·4 B/row (the rowpatch variant adds k_b²·4,
    folded into the same bound)."""
    per_row = 4 * ((k_b + 3) * d_pad + k_b * k_b + 4 * k_b)
    return fit_block_rows(per_row, n_rows=n_rows)


def topk_block_items(block_b: int, d_pad: int, k_pad: int, *, n_items: int | None = None) -> int:
    """ψ-table row tile for the ``topk_score`` kernel.

    Per ψ row: the ψ tile lane (d_pad·4) plus this row's column in the
    (block_b, block_items) score tile and the concat/merge temporaries
    (≈3 score-tile copies: scores + concatenated scores/ids). Fixed: the
    resident φ tile and the running top-k_pad score/id blocks."""
    per_row = 4 * (d_pad + 4 * block_b)
    fixed = 4 * (block_b * d_pad + 4 * block_b * k_pad)
    return fit_block_rows(
        per_row, fixed_bytes=fixed, n_rows=n_items, multiple=128, lo=128, hi=4096
    )
