"""Request micro-batching for the online retrieval p99 path.

The fused ``topk_score`` kernel (and a TPU generally) is efficient at
kernel-shaped batches and terrible at B=1: a single-row query pays the whole
ψ-table stream by itself. Online traffic, however, ARRIVES one row at a
time. The :class:`MicroBatcher` closes that gap with the standard serving
trick — an admission queue that coalesces single-row queries into one padded
batch per kernel dispatch:

  flush policy (deadline/size):
    * SIZE — the queue reaching ``max_batch`` rows flushes immediately
      (admission of the triggering request included);
    * DEADLINE — otherwise a flush happens once ``now`` passes
      ``oldest.t_submit + max_delay``: no request waits longer than
      ``max_delay`` in the queue, bounding the batching-induced latency
      (the p99 knob);
    * callers drive time explicitly via :meth:`step` (or implicitly on
      every :meth:`submit`) — the batcher never sleeps or spawns threads,
      so tests run it under a SIMULATED clock.

  batch shaping: flushed rows are stacked and padded up to a multiple of
  ``pad_to`` φ rows (zero rows; results discarded), and the per-request
  exclude-id lists are right-padded with −1 to the widest list in the batch
  — exactly the (B, L) global-id form the kernel's exclude variant takes,
  so no (B, n_items) mask is built per request.

  routing: every request gets a ticket id at admission; after the flush the
  (k,) score/id rows are routed back to their tickets, so out-of-order
  submission, mixed flushes, and pad rows can never cross results between
  requests (parity-pinned in tests under a simulated clock).

  caching: an LRU φ→result cache keyed on ``(key, table_version,
  exclude_list)``. The version comes from the serving table
  (``cluster.version`` — bumped by every ``publish``), so a live ψ refresh
  implicitly invalidates the whole cache without any flush traffic; on the
  first admission AFTER a version bump every entry keyed on a superseded
  version is EVICTED outright (dead weight would otherwise squat in the
  LRU until capacity pressure aged it out, evicting live entries first).
  The exclude list is folded in by the batcher itself, so a caller key
  only has to identify the φ row. Only requests that carry an explicit
  hashable ``key`` participate (an unkeyed φ row has no cheap identity),
  and only full-coverage results are cached — a degraded answer
  (``coverage < 1``, see below) must not outlive the failure that caused
  it.

  degraded results: when the backing executor is the fault-tolerant mesh
  (``serve/mesh.py``), a flush's results may carry ``coverage < 1.0`` and
  dead item ranges. The batcher forwards that contract per ticket: each
  routed result is a single-row :class:`~repro.serve.cluster.TopKResult`
  (still unpackable as ``(scores, ids)``) tagged with the flush's
  coverage/dead ranges — a caller can always tell a full answer from a
  partial one.

  shutdown: :meth:`drain` flushes everything queued and closes the
  batcher — queued requests are never stranded; admissions after close
  raise. The serving driver calls it on the way out (and on SIGTERM in a
  real deployment).
"""
from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict
from typing import Callable, Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.obs.metrics import StatsView, next_instance_id, resolve_registry
from repro.serve.cluster import TopKResult

_FLUSH_REASONS = ("size", "deadline", "forced", "drained")


@dataclasses.dataclass
class _Pending:
    ticket: int
    phi_row: np.ndarray            # (D,)
    exclude: Optional[np.ndarray]  # (L,) global ids or None
    key: Optional[object]
    t_submit: float


class MicroBatcher:
    """Coalesce single-row top-K queries into kernel-shaped batches.

    ``topk_phi(phi_rows (B, D), exclude_ids (B, L) | None) -> (scores, ids)``
    is the backing batch executor — typically
    ``cluster.topk_phi`` / ``engine.topk_phi`` with exclusion passed through.

    ::

        batcher = MicroBatcher(
            lambda phi, eids: cluster.topk_phi(phi, exclude_ids=eids),
            max_batch=32, max_delay=2e-3, version_fn=lambda: cluster.version)
        t1 = batcher.submit(phi_row, exclude=[3, 7], key=("user", 17))
        ...
        batcher.step()            # deadline check; flush if due
        scores, ids = batcher.result(t1)   # None until flushed

    The batcher is deliberately single-threaded and clock-injected: the
    serving loop owns the cadence (call ``step`` between admissions), and
    the unit tests replay traces under a simulated clock.
    """

    def __init__(
        self,
        topk_phi: Callable,
        *,
        max_batch: int = 64,
        max_delay: float = 2e-3,
        pad_to: int = 8,
        clock: Callable[[], float] = time.monotonic,
        cache_size: int = 4096,
        version_fn: Optional[Callable[[], int]] = None,
        registry=None,
        tracer=None,
    ):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        self.topk_phi = topk_phi
        self.max_batch = int(max_batch)
        self.max_delay = float(max_delay)
        self.pad_to = int(pad_to)
        self.clock = clock
        self.version_fn = version_fn or (lambda: 0)
        self._queue: List[_Pending] = []
        self._results: Dict[int, TopKResult] = {}
        self._completed_at: Dict[int, float] = {}
        self._next_ticket = 0
        self._cache: OrderedDict = OrderedDict()
        self._cache_size = int(cache_size)
        self._cache_version = self.version_fn()
        self._closed = False
        # counters live on the metrics registry (obs/metrics.py);
        # ``self.stats`` stays a live read-only view over them so every
        # pre-registry caller (tests, benches, drivers) keeps working.
        # ``registry=None`` → the process default (per-instance labels
        # keep two batchers' counters apart); NULL_REGISTRY → bare mode.
        # ``tracer`` (obs/trace.py) opts into per-request spans.
        self.registry = resolve_registry(registry)
        self.tracer = tracer
        self._spans: Dict[int, tuple] = {}   # ticket -> (request, queue) spans
        reg, inst = self.registry, next_instance_id()
        lab = ("instance",)

        def _c(name, help_text):
            return reg.counter(name, help_text, labels=lab).labels(
                instance=inst)

        self._m_submitted = _c(
            "serve_batcher_submitted_total", "requests admitted")
        self._m_flushed_rows = _c(
            "serve_batcher_flushed_rows_total", "real (non-pad) rows flushed")
        self._m_cache_hits = _c(
            "serve_batcher_cache_hits_total", "keyed-result cache hits")
        self._m_cache_misses = _c(
            "serve_batcher_cache_misses_total", "keyed-result cache misses")
        self._m_cache_evicted = _c(
            "serve_batcher_cache_evicted_stale_total",
            "cache entries evicted on a table-version bump")
        self._m_degraded = _c(
            "serve_batcher_degraded_results_total",
            "routed results with coverage < 1")
        flush_fam = reg.counter(
            "serve_batcher_flushes_total", "flushes by trigger reason",
            labels=("instance", "reason"))
        self._m_flush = {r: flush_fam.labels(instance=inst, reason=r)
                         for r in _FLUSH_REASONS}
        self._m_queue_depth = reg.gauge(
            "serve_batcher_queue_depth", "requests waiting in the admission "
            "queue", labels=lab).labels(instance=inst)
        self._m_queue_lat = reg.histogram(
            "serve_batcher_queue_latency_seconds",
            "per-ticket submit->flush wait", labels=lab).labels(instance=inst)
        self.stats = StatsView({
            "submitted": lambda: int(self._m_submitted.value),
            "flushes": lambda: int(sum(
                ch.value for ch in self._m_flush.values())),
            "flushed_rows": lambda: int(self._m_flushed_rows.value),
            "flush_by_size": lambda: int(self._m_flush["size"].value),
            "flush_by_deadline":
                lambda: int(self._m_flush["deadline"].value),
            "flush_forced": lambda: int(self._m_flush["forced"].value),
            "drained": lambda: int(self._m_flush["drained"].value),
            "cache_hits": lambda: int(self._m_cache_hits.value),
            "cache_misses": lambda: int(self._m_cache_misses.value),
            "cache_evicted_stale":
                lambda: int(self._m_cache_evicted.value),
            "degraded_results": lambda: int(self._m_degraded.value),
        })

    # ----------------------------------------------------------- admission
    def submit(
        self,
        phi_row,
        *,
        exclude=None,
        key: Optional[object] = None,
        now: Optional[float] = None,
    ) -> int:
        """Admit one single-row query; returns its ticket id.

        ``exclude`` is this request's global excluded-id list (seen items).
        ``key`` opts into the result cache and only has to identify the φ
        row (e.g. the user id): the exclude list and the table version are
        folded into the cache key here, so a request with a different
        exclusion set or against a newer ψ table can never be served a
        stale cached result."""
        if self._closed:
            raise RuntimeError(
                "batcher is closed (drained); no new admissions"
            )
        now = self.clock() if now is None else now
        self._evict_superseded()
        ticket = self._next_ticket
        self._next_ticket += 1
        self._m_submitted.inc()
        rq = None
        if self.tracer is not None:
            rq = self.tracer.begin("request", parent=None, ticket=ticket)
        excl = None
        if exclude is not None:
            excl = np.asarray(exclude, np.int32).reshape(-1)
        if key is not None:
            hit = self._cache_get(self._cache_key(key, excl))
            if hit is not None:
                self._m_cache_hits.inc()
                self._results[ticket] = hit
                self._completed_at[ticket] = now
                if rq is not None:
                    self.tracer.end(rq, cache="hit")
                self.step(now)  # a hit must still retire queue deadlines
                return ticket
            self._m_cache_misses.inc()
        if rq is not None:
            qs = self.tracer.begin("queue", parent=rq, ticket=ticket)
            self._spans[ticket] = (rq, qs)
        self._queue.append(_Pending(
            ticket=ticket,
            phi_row=np.asarray(phi_row, np.float32).reshape(-1),
            exclude=excl, key=key, t_submit=now,
        ))
        self._m_queue_depth.set(len(self._queue))
        if len(self._queue) >= self.max_batch:
            self._flush(now, "size")
        else:
            self.step(now)  # admission also retires an overdue deadline
        return ticket

    # ---------------------------------------------------------------- time
    def step(self, now: Optional[float] = None) -> bool:
        """Flush iff the oldest queued request's deadline has passed.
        Returns whether a flush happened."""
        if not self._queue:
            return False
        now = self.clock() if now is None else now
        if now - self._queue[0].t_submit >= self.max_delay:
            self._flush(now, "deadline")
            return True
        return False

    def flush(self, now: Optional[float] = None) -> None:
        """Force-flush everything queued."""
        now = self.clock() if now is None else now
        while self._queue:
            self._flush(now, "forced")

    # ------------------------------------------------------------- shutdown
    def drain(self, now: Optional[float] = None) -> Dict[int, TopKResult]:
        """Graceful shutdown: flush every queued request so none is
        stranded, CLOSE the batcher (subsequent ``submit`` raises), and
        return all still-unclaimed results keyed by ticket so the caller
        can deliver them before exiting. Idempotent. Flushes performed
        here count under the ``drained`` reason (``stats["drained"]``) so
        a shutdown flush is distinguishable from a deadline one."""
        now = self.clock() if now is None else now
        while self._queue:
            self._flush(now, "drained")
        self._closed = True
        out = dict(self._results)
        self._results.clear()
        self._completed_at.clear()
        return out

    @property
    def closed(self) -> bool:
        return self._closed

    # -------------------------------------------------------------- results
    def result(
        self, ticket: int, *, pop: bool = True
    ) -> Optional[TopKResult]:
        """Single-row :class:`~repro.serve.cluster.TopKResult` for a ticket
        (unpacks as ``scores (k,), ids (k,)``; carries the flush's
        ``coverage``/``dead_ranges``), or None while queued."""
        if ticket not in self._results:
            return None
        out = self._results.pop(ticket) if pop else self._results[ticket]
        if pop:
            self._completed_at.pop(ticket, None)
        return out

    def completed_at(self, ticket: int) -> Optional[float]:
        """Completion timestamp of a finished ticket (latency accounting)."""
        return self._completed_at.get(ticket)

    @property
    def n_queued(self) -> int:
        return len(self._queue)

    # ------------------------------------------------------------ internals
    def _flush(self, now: float, reason: str) -> None:
        batch, self._queue = self._queue[: self.max_batch], self._queue[self.max_batch:]
        self._m_queue_depth.set(len(self._queue))
        b = len(batch)
        b_pad = -(-b // self.pad_to) * self.pad_to
        phi = np.zeros((b_pad, batch[0].phi_row.shape[0]), np.float32)
        for r, req in enumerate(batch):
            phi[r] = req.phi_row
        excl_ids = None
        l_max = max((req.exclude.shape[0] for req in batch
                     if req.exclude is not None), default=0)
        if l_max > 0:
            excl_ids = np.full((b_pad, l_max), -1, np.int32)
            for r, req in enumerate(batch):
                if req.exclude is not None:
                    excl_ids[r, : req.exclude.shape[0]] = req.exclude
            excl_ids = jnp.asarray(excl_ids)
        fs = None
        if self.tracer is not None:
            # explicit begin/end (not a context manager): _flush is
            # non-reentrant via the trailing step() and the span must
            # close before that follow-up flush opens its own
            fs = self.tracer.begin("flush", parent=None, reason=reason,
                                   batch=b, batch_padded=b_pad)
            with self.tracer.activate(fs):   # mesh spans nest under it
                res = self.topk_phi(jnp.asarray(phi), excl_ids)
        else:
            res = self.topk_phi(jnp.asarray(phi), excl_ids)
        scores, ids = res  # TopKResult or a bare (scores, ids) tuple
        coverage = float(getattr(res, "coverage", 1.0))
        dead_ranges = tuple(getattr(res, "dead_ranges", ()))
        scores = np.asarray(scores)
        ids = np.asarray(ids)
        if coverage < 1.0:
            self._m_degraded.inc(len(batch))
        for r, req in enumerate(batch):  # route rows back to their tickets
            out = TopKResult(scores[r], ids[r], coverage, dead_ranges)
            self._results[req.ticket] = out
            self._completed_at[req.ticket] = now
            self._m_queue_lat.observe(now - req.t_submit)
            spans = self._spans.pop(req.ticket, None)
            if spans is not None:
                rq, qs = spans
                self.tracer.end(qs)
                self.tracer.end(rq, flush_span=fs.span_id,
                                coverage=coverage)
            # degraded answers are never cached: the hole they carry must
            # not outlive the replica failure that caused it
            if req.key is not None and coverage == 1.0:
                self._cache_put(self._cache_key(req.key, req.exclude), out)
        if fs is not None:
            self.tracer.end(fs, coverage=coverage)
        self._m_flushed_rows.inc(b)
        self._m_flush[reason].inc()
        if self._queue:  # drain backlog left by a size-capped flush
            self.step(now)

    def _cache_key(self, key, excl: Optional[np.ndarray]):
        """(caller key, table version, exclude list) — version comes from
        the live table so a publish implicitly invalidates every entry."""
        excl_key = () if excl is None else tuple(excl.tolist())
        return (key, self.version_fn(), excl_key)

    def _evict_superseded(self) -> None:
        """Drop cache entries keyed on a superseded table version the
        moment a publish is observed — they can never hit again (the key
        embeds the version), so letting them age out of the LRU would only
        crowd out live entries."""
        version = self.version_fn()
        if version == self._cache_version:
            return
        self._cache_version = version
        stale = [k for k in self._cache if k[1] != version]
        for k in stale:
            del self._cache[k]
        self._m_cache_evicted.inc(len(stale))

    def _cache_get(self, key):
        if key not in self._cache:
            return None
        self._cache.move_to_end(key)
        return self._cache[key]

    def _cache_put(self, key, value) -> None:
        self._cache[key] = value
        self._cache.move_to_end(key)
        while len(self._cache) > self._cache_size:
            self._cache.popitem(last=False)
