"""End-to-end training driver.

On a pod this is the per-host entry point (jax.distributed.initialize, then
identical SPMD code); in this container it runs the same path on the local
device mesh. Supports every ``--arch`` in the registry:

  python -m repro.launch.train --arch gemma2-2b --smoke --steps 20
  python -m repro.launch.train --arch icd-mf --smoke --steps 30
  python -m repro.launch.train --arch dlrm-rm2 --smoke --steps 50
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import Checkpointer
from repro.configs import get_config, get_smoke_config
from repro.data.loader import lm_token_batches, sharded_batches
from repro.optim import adamw
from repro.train.train_step import build_train_step, init_state
from repro.train.trainer import Trainer


def _lm_main(cfg, args):
    from repro.models import transformer as T

    params = T.init_params(jax.random.PRNGKey(args.seed), cfg)
    opt = adamw(args.lr)
    step = jax.jit(build_train_step(
        lambda p, b: T.loss_fn(cfg, p, b["tokens"], b["targets"],
                               compute_dtype=jnp.float32 if args.smoke else jnp.bfloat16),
        opt, num_microbatches=cfg.num_microbatches,
    ))
    data = (
        {"tokens": jnp.asarray(b["tokens"]), "targets": jnp.asarray(b["targets"])}
        for b in lm_token_batches(cfg.vocab, args.batch, args.seq, seed=args.seed)
    )
    ck = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None
    trainer = Trainer(step, init_state(params, opt), data, checkpointer=ck,
                      ckpt_every=args.ckpt_every)
    trainer.run(args.steps)
    return trainer


def _recsys_main(cfg, args):
    from repro.launch.cells import _recsys_module

    mod = _recsys_module(cfg)
    params = mod.init_params(jax.random.PRNGKey(args.seed), cfg)
    opt = adamw(args.lr)
    step = jax.jit(build_train_step(lambda p, b: mod.loss_fn(cfg, p, b), opt))

    def make_batch(rng, n):
        if cfg.kind in ("dlrm", "dcn"):
            return {
                "dense": jnp.asarray(rng.normal(size=(n, cfg.n_dense)), jnp.float32),
                "sparse": jnp.asarray(
                    rng.integers(0, min(cfg.table_vocabs), (n, cfg.n_sparse)),
                    jnp.int32),
                "label": jnp.asarray(rng.integers(0, 2, n), jnp.float32),
            }
        return {
            "hist": jnp.asarray(rng.integers(0, cfg.item_vocab, (n, cfg.seq_len)),
                                jnp.int32),
            "mask": jnp.asarray(rng.integers(0, 2, (n, cfg.seq_len)), jnp.float32),
            "target": jnp.asarray(rng.integers(0, cfg.item_vocab, n), jnp.int32),
            "label": jnp.asarray(rng.integers(0, 2, n), jnp.float32),
        }

    data = sharded_batches(make_batch, args.batch, seed=args.seed)
    ck = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None
    trainer = Trainer(step, init_state(params, opt), data, checkpointer=ck,
                      ckpt_every=args.ckpt_every)
    trainer.run(args.steps)
    return trainer


def _icd_main(cfg, args):
    from repro.core.models import mf
    from repro.data.synthetic import make_implicit_dataset
    from repro.sparse.interactions import build_interactions

    ds = make_implicit_dataset(n_users=cfg.n_ctx, n_items=cfg.n_items,
                               seed=args.seed)
    ev = ds.events
    hp = mf.MFHyperParams(k=cfg.k, alpha0=cfg.alpha0, l2=cfg.l2)
    data = build_interactions(
        ev[:, 0], ev[:, 1], np.ones(len(ev)), np.full(len(ev), cfg.alpha0 + 2.0),
        cfg.n_ctx, cfg.n_items, alpha0=cfg.alpha0,
    )
    params = mf.init(jax.random.PRNGKey(args.seed), cfg.n_ctx, cfg.n_items, cfg.k)
    for ep in range(args.steps):
        params = mf.fit(params, data, hp, 1)
        if (ep + 1) % 5 == 0:
            obj = float(mf.objective(params, data, hp))
            print(f"[icd] epoch {ep + 1} objective {obj:.4f}")
    return params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=100)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    name = getattr(cfg, "name", args.arch)
    print(f"[train] arch={name} smoke={args.smoke}")
    if args.arch.startswith("icd"):
        _icd_main(cfg, args)
    elif args.arch in ("dlrm-rm2", "din", "dcn-v2", "bst"):
        _recsys_main(cfg, args)
    elif args.arch == "graphsage-reddit":
        raise SystemExit("use examples/gnn_train.py for the GNN driver")
    else:
        _lm_main(cfg, args)


if __name__ == "__main__":
    main()
