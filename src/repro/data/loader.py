"""Host-sharded batch iterators.

Each host yields only its slice of the global batch (slice index =
``jax.process_index()``); on a pod the per-host arrays are assembled into
globally-sharded jax.Arrays by the launcher via
``jax.make_array_from_process_local_data``. In this single-process container
the iterator degenerates to the full batch, same code path.
"""
from __future__ import annotations

from typing import Dict, Iterator

import jax
import numpy as np


def _host_slice(global_batch: int) -> slice:
    n_hosts = jax.process_count()
    per_host = global_batch // n_hosts
    lo = jax.process_index() * per_host
    return slice(lo, lo + per_host)


def interaction_stream(
    ds, *, batch_events: int = 1024, start: int = 0
) -> Iterator[Dict[str, np.ndarray]]:
    """Time-ordered replay of a
    :class:`~repro.data.synthetic.SyntheticImplicitDataset`: yields the
    ``(user, item, t)`` event log in arrival order, ``batch_events`` at a
    time — the traffic source for the continual-learning loop (fold-in +
    delta ψ publish; see ``examples/continual_learning.py``).

    Unlike the epoch loaders this iterator is FINITE (a log replay, not a
    sampler) and the final partial batch is yielded. Each host takes its
    contiguous slice of every batch; in a single-process container that
    degenerates to the full batch.
    """
    events = np.asarray(ds.events)
    for lo in range(int(start), len(events), int(batch_events)):
        chunk = events[lo : lo + batch_events]
        sl = _host_slice(len(chunk))
        part = chunk[sl] if jax.process_count() > 1 else chunk
        yield {
            "ctx": part[:, 0].astype(np.int32),
            "item": part[:, 1].astype(np.int32),
            "t": part[:, 2].astype(np.int64),
        }


def sharded_batches(
    make_batch, global_batch: int, seed: int = 0
) -> Iterator[Dict[str, np.ndarray]]:
    """Generic host-sharded iterator: make_batch(rng, n) → dict of arrays."""
    rng = np.random.default_rng(seed + jax.process_index())
    sl = _host_slice(global_batch)
    n = sl.stop - sl.start
    while True:
        yield make_batch(rng, n)
